"""Fault-tolerant datapath: seeded chaos injection (FaultPlan), backend
health/failover (HealthTable + the in-plane live-rule column), bounded
retry/backoff with timeout-drop, worker-failure flow migration, and
epoch-versioned policy hot-swap — property-tested against fault-free
runs: every non-dropped message is byte-identical, every drop is
counted, and no pool ever leaks a page or a grant pin."""
import time

import numpy as np
import pytest

from repro.core import (
    ClusterRuntime,
    FaultPlan,
    HealthTable,
    LibraCluster,
    LibraStack,
    PolicyTable,
    ProxyRuntime,
    build_message,
    eq,
    forward,
    rule,
)

STACK_KW = dict(n_shards=4, pages_per_shard=128, page_size=16)

#: app metadata starts after the [MAGIC, len_meta, len_payload] header
TAG = 3


def _stack(**kw):
    for k, v in STACK_KW.items():
        kw.setdefault(k, v)
    kw.setdefault("secret", b"ft")
    return LibraStack(**kw)


def _fo_table(health=None):
    """One forward rule with a declared failover backend."""
    return PolicyTable([rule(forward(0, failover=1), eq(TAG, 7))],
                       health=health)


def _deliver(src, n, seed=0, tag=7, payload=24, tls=False):
    rng = np.random.default_rng(seed)
    frames = [build_message(np.concatenate([[tag], rng.integers(100, 200, 3)]),
                            rng.integers(1000, 2000, payload))
              for _ in range(n)]
    wire = (src.tls.seal_frames(frames, src.parser.inner) if tls
            else np.concatenate(frames))
    src.deliver(wire)
    return frames


def _frames_of(wire):
    """Split a backend tx wire back into [MAGIC, lm, lp, meta..., payload...]
    frames (hashable tuples, for multiset identity checks)."""
    w = np.asarray(wire)
    out, pos = [], 0
    while pos < len(w):
        span = 3 + int(w[pos + 1]) + int(w[pos + 2])
        out.append(tuple(int(x) for x in w[pos:pos + span]))
        pos += span
    return out


# ---------------------------------------------------------------------------
# FaultPlan determinism
# ---------------------------------------------------------------------------

def test_fault_plan_replay_is_deterministic():
    """The same seed and schedule replay to identical fired-event logs,
    channel stats, and backend bytes — chaos runs are reproducible."""
    def run():
        st = _stack()
        plan = (FaultPlan(seed=11)
                .eagain(0, start=1, until=9, p=0.6)
                .reset(1, at=4)
                .corrupt(p=0.3, start=0, until=2))
        rt = ProxyRuntime(st, fault_plan=plan)
        src = st.socket()
        d0, d1 = st.socket(), st.socket()
        ch = rt.channel(src, [d0, d1], max_retries=4, retry_timeout=64)
        _deliver(src, 8, seed=3)
        rt.run()
        wires = (np.array(d0.tx_wire()), np.array(d1.tx_wire()))
        out = (list(plan.log), plan.summary(),
               (ch.stats.messages, ch.stats.retries, ch.stats.timeouts),
               wires)
        rt.shutdown()
        return out

    log_a, sum_a, stats_a, wires_a = run()
    log_b, sum_b, stats_b, wires_b = run()
    assert log_a == log_b and sum_a == sum_b and stats_a == stats_b
    for a, b in zip(wires_a, wires_b):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# bounded retries, timeout-drop, storm ride-out
# ---------------------------------------------------------------------------

def test_permanent_stall_bounded_retries_then_counted_timeout_drop():
    """An unexplained EAGAIN storm with no failover target must NOT hold
    pages forever: each message retries (with backoff) up to the cap,
    then drops — counted in ``ChannelStats.timeouts`` — and its pages
    free. The run terminates (no EAGAIN livelock)."""
    st = _stack()
    plan = FaultPlan(seed=1).stall(0)
    rt = ProxyRuntime(st, fault_plan=plan)
    src, dst = st.socket_pair()
    ch = rt.channel(src, dst, max_retries=5)
    _deliver(src, 6)
    rt.run()
    assert ch.stats.timeouts == 6 and ch.stats.messages == 0
    assert ch.stats.retries > 0
    assert len(dst.tx_wire()) == 0
    rt.shutdown()
    assert st.alloc.free_pages == st.alloc.total_pages


def test_retry_rides_out_finite_storm_byte_identical():
    """A storm that ends inside the retry budget costs retries but no
    messages: the delivered bytes equal the fault-free run."""
    def run(faulty):
        st = _stack()
        plan = FaultPlan(seed=2).eagain(0, start=0, until=4, p=0.8) \
            if faulty else None
        rt = ProxyRuntime(st, fault_plan=plan)
        src, dst = st.socket_pair()
        ch = rt.channel(src, dst)
        _deliver(src, 6, seed=9)
        rt.run()
        wire = np.array(dst.tx_wire())
        snap = st.counters.snapshot()
        retries = ch.stats.retries
        rt.shutdown()
        assert st.alloc.free_pages == st.alloc.total_pages
        return wire, snap, retries

    ref_wire, ref_snap, _ = run(False)
    wire, snap, retries = run(True)
    assert retries > 0
    assert np.array_equal(wire, ref_wire)
    assert snap == ref_snap


# ---------------------------------------------------------------------------
# backend health: trip, in-plane failover, half-open recovery
# ---------------------------------------------------------------------------

def test_health_trips_and_traffic_fails_over_in_plane():
    """A hard-stalled primary trips the circuit breaker after
    ``fail_threshold`` unexplained failures; subsequent verdicts (and the
    held retry) re-route to the rule's failover backend — nothing times
    out, everything lands on backend 1."""
    st = _stack()
    health = HealthTable(2, fail_threshold=3, probe_after=10 ** 6)
    table = _fo_table(health)
    plan = FaultPlan(seed=1).stall(0)
    rt = ProxyRuntime(st, policy=table, fault_plan=plan)
    src = st.socket()
    d0, d1 = st.socket(), st.socket()
    ch = rt.channel(src, [d0, d1])
    _deliver(src, 6)
    rt.run()
    assert ch.stats.messages == 6 and ch.stats.timeouts == 0
    assert ch.stats.failovers >= 1          # the held send re-routed
    assert table.stats["failovers"] >= 1    # later verdicts re-routed
    assert health.summary()["trips"] >= 1
    assert len(d0.tx_wire()) == 0 and len(d1.tx_wire()) > 0
    rt.shutdown()
    assert st.alloc.free_pages == st.alloc.total_pages


def test_health_half_open_probe_recovers_primary():
    """After the storm window closes, the half-open probe's first success
    closes the breaker and traffic returns to the primary."""
    st = _stack()
    health = HealthTable(2, fail_threshold=2, probe_after=1)
    table = _fo_table(health)
    plan = FaultPlan(seed=4).stall(0, until=6)
    rt = ProxyRuntime(st, policy=table, fault_plan=plan, tick_every=4)
    src = st.socket()
    d0, d1 = st.socket(), st.socket()
    ch = rt.channel(src, [d0, d1])
    _deliver(src, 6, seed=1)
    rt.run()
    w0 = len(d0.tx_wire())
    _deliver(src, 6, seed=2)
    rt.run()
    s = health.summary()
    assert s["trips"] >= 1 and s["recoveries"] >= 1
    assert s["state"] == [0, 0]             # both healthy again
    assert len(d0.tx_wire()) > w0           # post-recovery traffic on d0
    assert ch.stats.messages == 12 and ch.stats.timeouts == 0
    rt.shutdown()


def test_reset_backend_reroutes_to_failover():
    """A connection reset closes the backend; in-flight and subsequent
    messages re-route to the failover instead of dropping."""
    st = _stack()
    health = HealthTable(2, fail_threshold=3)
    table = _fo_table(health)
    plan = FaultPlan(seed=5).reset(0, at=0)
    rt = ProxyRuntime(st, policy=table, fault_plan=plan)
    src = st.socket()
    d0, d1 = st.socket(), st.socket()
    ch = rt.channel(src, [d0, d1])
    frames = _deliver(src, 5, seed=7)
    rt.run()
    assert d0.closed and len(d0.tx_wire()) == 0
    assert ch.stats.messages == 5 and ch.stats.timeouts == 0
    assert ch.stats.failovers + table.stats["failovers"] >= 1
    assert _frames_of(d1.tx_wire()) == [tuple(int(x) for x in f)
                                        for f in frames]
    rt.shutdown()
    assert st.alloc.free_pages == st.alloc.total_pages


def test_rule_live_column_skips_dead_rule_in_batched_match():
    """The health column rides the vectorized match as a dense live mask:
    a FORWARD rule whose primary is down (no failover) goes dead and
    priority falls through to the next rule — in the batched pass."""
    st = _stack()
    health = HealthTable(2, fail_threshold=1)
    table = PolicyTable([rule(forward(0), eq(TAG, 7), name="primary"),
                         rule(forward(1), eq(TAG, 7), name="shadow")],
                        health=health)
    health.mark_down(0)
    assert list(table.rule_live()) == [0, 1]
    rt = ProxyRuntime(st, policy=table, batched=True)
    src = st.socket()
    d0, d1 = st.socket(), st.socket()
    ch = rt.channel(src, [d0, d1])
    _deliver(src, 6)
    rt.run()
    assert ch.stats.messages == 6
    assert len(d0.tx_wire()) == 0 and len(d1.tx_wire()) > 0
    assert table.stats["rule_hits"][1] == 6
    rt.shutdown()


# ---------------------------------------------------------------------------
# epoch-versioned policy hot-swap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batched", [False, True])
def test_policy_hot_swap_under_traffic(batched):
    """``PolicyTable.swap`` under live traffic: messages verdicted before
    the swap keep routing to the old backend, later ones to the new —
    no message is lost or double-routed, and the epoch bumps."""
    st = _stack()
    table = PolicyTable([rule(forward(0), eq(TAG, 7))])
    plan = FaultPlan(seed=0)
    plan.at(3, lambda rt: table.swap([rule(forward(1), eq(TAG, 7))]))
    rt = ProxyRuntime(st, policy=table, fault_plan=plan, batched=batched)
    src = st.socket()
    d0, d1 = st.socket(), st.socket()
    ch = rt.channel(src, [d0, d1])
    frames = _deliver(src, 12, seed=3)
    rt.run()
    assert table.epoch == 1
    assert ch.stats.messages == 12
    got = _frames_of(d0.tx_wire()) + _frames_of(d1.tx_wire())
    assert sorted(got) == sorted(tuple(int(x) for x in f) for f in frames)
    assert len(_frames_of(d0.tx_wire())) > 0    # pre-swap epoch routed old
    assert len(_frames_of(d1.tx_wire())) > 0    # post-swap epoch routed new
    rt.shutdown()
    assert st.alloc.free_pages == st.alloc.total_pages


# ---------------------------------------------------------------------------
# record corruption (frame-aware, detectable under kTLS)
# ---------------------------------------------------------------------------

def test_corrupt_ingress_rejected_by_ktls_auth_and_stream_recovers():
    """Injected corruption flips one payload token per record: the hw-kTLS
    auth tag catches every damaged record (counted, dropped), the stream
    never wedges, and post-window records deliver intact."""
    st = _stack()
    plan = FaultPlan(seed=3).corrupt(p=1.0, start=0, until=1)
    rt = ProxyRuntime(st, fault_plan=plan)
    src, dst = st.socket_pair("length-prefixed", tls="hw")
    ch = rt.channel(src, dst)
    _deliver(src, 4, seed=5, tls=True)
    rt.run()
    assert ch.stats.auth_rejects == 4 and ch.stats.messages == 0
    frames = _deliver(src, 4, seed=6, tls=True)
    rt.run()
    assert ch.stats.messages == 4
    opened = dst.tls.open_wire(dst.tx_wire())
    assert np.array_equal(opened, np.concatenate(frames))
    rt.shutdown()
    assert st.alloc.free_pages == st.alloc.total_pages


def test_pool_pressure_window_backpressures_then_drains():
    """Holding most of the pool's free pages for a window degrades but
    never deadlocks the datapath; the window closing (or shutdown's
    ``release_all``) returns the pages and the zero-leak shutdown
    invariant still holds."""
    st = _stack(n_shards=4, pages_per_shard=64)
    plan = FaultPlan(seed=2).pool_pressure(0.9, start=0, until=20)
    rt = ProxyRuntime(st, fault_plan=plan)
    src, dst = st.socket_pair()
    ch = rt.channel(src, dst)
    frames = _deliver(src, 8, seed=4, payload=40)
    rt.run()
    assert ch.stats.messages == 8
    assert np.array_equal(np.array(dst.tx_wire()), np.concatenate(frames))
    assert any(entry[1] == "pressure_on" for entry in plan.log)
    rt.shutdown()
    assert st.alloc.free_pages == st.alloc.total_pages


# ---------------------------------------------------------------------------
# worker failure: migration, dead-owner grants, zero leaks
# ---------------------------------------------------------------------------

def _cluster(n=3):
    return LibraCluster(n, secret=b"ft", **STACK_KW)


@pytest.mark.parametrize("batched", [False, True])
def test_kill_worker_migrates_flows_byte_identical(batched):
    """Killing a worker mid-run migrates its flows to survivors (ring
    remainder re-delivered, channel stats intact) — the survivors'
    delivered bytes equal the fault-free run, and nothing leaks."""
    rng = np.random.default_rng(5)
    frames = [[build_message(rng.integers(100, 200, 4),
                             rng.integers(1000, 2000, 40))
               for _ in range(6)] for _ in range(6)]

    def run(kill):
        cl = _cluster(3)
        plan = FaultPlan(seed=3)
        if kill:
            plan.kill_worker(2, at=4)
        crt = ClusterRuntime(cl, fault_plan=plan, batched=batched)
        dsts = []
        for i, chan_frames in enumerate(frames):
            src = cl.socket(worker=i % 3)
            dst = cl.socket(worker=0)
            crt.channel(src, dst)
            dsts.append(dst)
            for f in chan_frames:
                src.deliver(f)
        crt.run()
        wires = [np.array(d.tx_wire()) for d in dsts]
        stats = dict(cl.stats)
        crt.shutdown()       # asserts zero leaked pages/grants everywhere
        return wires, stats

    ref_wires, _ = run(False)
    wires, stats = run(True)
    assert stats["worker_kills"] == 1 and stats["migrated_flows"] >= 1
    for a, b in zip(ref_wires, wires):
        assert np.array_equal(a, b)


def test_kill_worker_migrates_ktls_session_state():
    """A kTLS flow survives its worker: the session object (keys +
    record sequence) moves with the migrated socket, so records sealed
    before AND after the kill open cleanly on the backend."""
    cl = _cluster(3)
    plan = FaultPlan(seed=1).kill_worker(2, at=3)
    crt = ClusterRuntime(cl, fault_plan=plan)
    src = cl.socket(worker=2, tls="hw")
    dst = cl.socket(worker=0, tls="hw")
    crt.channel(src, dst)
    frames = _deliver(src, 6, seed=8, tls=True)
    crt.run()
    assert cl.stats["worker_kills"] == 1
    opened = dst.tls.open_wire(dst.tx_wire())
    assert np.array_equal(opened, np.concatenate(frames))
    crt.shutdown()


def test_kill_worker_copies_out_dead_owner_grants_zero_leaks():
    """A grant whose OWNER dies must not dangle: the grantee's entry is
    copied out of the dying pool (counted one-copy fallback), the pin is
    released, the dead pool drains to fully-free, and the granted payload
    is still transmittable from the stash."""
    from repro.core import VpiRegistry

    cl = _cluster(2)
    w0, w1 = cl.workers
    crt = ClusterRuntime(cl)
    src = cl.socket(worker=0)
    dst = cl.socket(worker=1)
    payload = np.arange(1000, 1040)
    src.deliver(build_message(np.array([7, 1, 2, 3]), payload))
    buf, _ = src.recv(1 << 20)
    vpi = next(iter(src.connection.anchored))
    granted = cl.grant_into(w1, vpi)
    assert granted is not None and w0.alloc.granted_out_pages > 0

    info = crt.kill_worker(0)
    assert info["grants_copied_out"] == 1
    assert cl.stats["dead_grants_copied"] == 1
    assert w0.alloc.granted_out_pages == 0
    assert w0.alloc.free_pages == w0.alloc.total_pages

    out = buf.copy()
    out[-1] = VpiRegistry.to_token(granted)
    dst.send(out)
    assert np.array_equal(np.array(dst.tx_wire())[-len(payload):], payload)
    crt.shutdown()


# ---------------------------------------------------------------------------
# chaos matrix: byte- and counter-identity vs the fault-free run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topology", ["single", "cluster"])
@pytest.mark.parametrize("tls", [None, "hw"])
@pytest.mark.parametrize("batched", [False, True])
def test_chaos_storm_identity_matrix(topology, tls, batched):
    """A finite EAGAIN storm (a fully-recovering fault) across the whole
    configuration matrix: scalar/batched × plaintext/hw-kTLS ×
    single-stack/cluster. The chaos run must deliver byte-identical
    backend bytes AND an identical Fig. 9 counter snapshot — retries are
    scheduling events, not data-plane copies."""
    n_chans, n_msgs = 4, 4

    def run(faulty):
        plan = (FaultPlan(seed=7).eagain(0, start=1, until=5, p=0.7)
                if faulty else None)
        if topology == "single":
            st = _stack()
            rt = ProxyRuntime(st, batched=batched, fault_plan=plan)
            mk = lambda i: (st.socket("length-prefixed", tls=tls),
                            st.socket("length-prefixed", tls=tls))
            counters = lambda: st.counters.snapshot()
            pool_ok = lambda: st.alloc.free_pages == st.alloc.total_pages
        else:
            cl = _cluster(2)
            rt = ClusterRuntime(cl, batched=batched, fault_plan=plan)
            mk = lambda i: (cl.socket("length-prefixed", worker=i % 2,
                                      tls=tls),
                            cl.socket("length-prefixed", worker=(i + 1) % 2,
                                      tls=tls))
            counters = lambda: cl.counters_aggregate().snapshot()
            pool_ok = lambda: cl.pages_in_use == 0
        dsts, retries = [], 0
        chans = []
        for i in range(n_chans):
            src, dst = mk(i)
            chans.append(rt.channel(src, dst))
            dsts.append(dst)
            _deliver(src, n_msgs, seed=100 + i, tls=tls is not None)
        rt.run()
        wires = [np.array(d.tls.open_wire(d.tx_wire()) if tls
                          else d.tx_wire()) for d in dsts]
        snap = counters()
        retries = sum(c.stats.retries for c in chans)
        rt.shutdown()
        assert pool_ok()
        return wires, snap, retries

    ref_wires, ref_snap, _ = run(False)
    wires, snap, retries = run(True)
    assert retries > 0, "the storm never bit — the matrix cell is vacuous"
    assert snap == ref_snap
    for a, b in zip(ref_wires, wires):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# the standard chaos scenario (tier-1 acceptance)
# ---------------------------------------------------------------------------

def _run_scenario(chaos: bool, n_chans=9, n_msgs=12, payload=32):
    """Backend 0 dead at t=25%, worker 2 killed at t=50%, tables swapped
    (equivalent rules, epoch bump) at t=75% — fractions of the fault-free
    round count. Returns per-channel delivered frame multisets, drop
    counts, wall seconds, and delivered message count."""
    cl = LibraCluster(3, secret=b"chaos", **STACK_KW)
    health = HealthTable(2, fail_threshold=2)
    table = _fo_table(health)
    plan = FaultPlan(seed=13)
    crt = ClusterRuntime(cl, policy=table, fault_plan=plan)
    if chaos:
        R = _run_scenario.rounds
        plan.reset(0, at=max(R // 4, 1))
        plan.kill_worker(2, at=max(R // 2, 2))

        def swap_all(rt):
            for t in rt.policies:
                if t is not None:
                    t.swap([rule(forward(0, failover=1), eq(TAG, 7))])
        plan.at(max(3 * R // 4, 3), swap_all)
    chans, dst_pairs, sent = [], [], []
    for i in range(n_chans):
        src = cl.socket(worker=i % 3)
        pair = [cl.socket(worker=(i + 1) % 3) for _ in range(2)]
        chans.append(crt.channel(src, pair))
        dst_pairs.append(pair)
        sent.append(_deliver(src, n_msgs, seed=200 + i, payload=payload))
    t0 = time.perf_counter()
    crt.run()
    dt = time.perf_counter() - t0
    if not chaos:
        _run_scenario.rounds = crt.rounds
    delivered = [sorted(_frames_of(d0.tx_wire()) + _frames_of(d1.tx_wire()))
                 for d0, d1 in dst_pairs]
    drops = [c.stats.timeouts + c.stats.drops for c in chans]
    msgs = crt.messages_forwarded()
    if chaos:
        assert cl.stats["worker_kills"] == 1
        assert all(t is None or t.epoch == 1 for t in crt.policies
                   if t is not None)
    crt.shutdown()         # asserts zero leaked pages/grants on every pool
    return delivered, drops, dt, msgs, [
        sorted(tuple(int(x) for x in f) for f in s) for s in sent]


def test_standard_chaos_scenario_identity_and_recovery_throughput():
    """The acceptance scenario: under backend-death + worker-kill +
    table-swap, every non-dropped message arrives byte-identical to the
    fault-free run (exactly once), every missing message is a counted
    drop, no pool leaks, and delivered throughput stays >= 70% of
    steady state."""
    ref_delivered, ref_drops, ref_dt, ref_msgs, sent = _run_scenario(False)
    assert sum(ref_drops) == 0 and ref_msgs == sum(len(s) for s in sent)
    for got, exp in zip(ref_delivered, sent):
        assert got == exp

    delivered, drops, dt, msgs, _ = _run_scenario(True)
    for i, (got, exp) in enumerate(zip(delivered, sent)):
        # subset: every delivered frame is one of the originals, once
        assert len(got) == len(set(got))
        assert set(got) <= set(exp), f"channel {i} delivered foreign bytes"
        # conservation: delivered + counted drops == sent
        assert len(got) + drops[i] == len(exp), \
            f"channel {i}: {len(exp) - len(got) - drops[i]} uncounted losses"

    # recovery throughput: wall-clock ratios of ~50ms runs are noisy on a
    # shared box, so take best-of-N on BOTH sides, re-measuring up to
    # three times before declaring a real regression (the same
    # confirmation-re-run idiom as scripts/check_bench_trend.py)
    steady_dts = [ref_dt]
    chaos_rates = [msgs / max(dt, 1e-9)]
    for _ in range(3):
        steady = ref_msgs / max(min(steady_dts), 1e-9)
        under_chaos = max(chaos_rates)
        if under_chaos >= 0.7 * steady:
            break
        _, _, ref_dt2, _, _ = _run_scenario(False)
        _, _, dt2, msgs2, _ = _run_scenario(True)
        steady_dts.append(ref_dt2)
        chaos_rates.append(msgs2 / max(dt2, 1e-9))
    else:
        steady = ref_msgs / max(min(steady_dts), 1e-9)
        under_chaos = max(chaos_rates)
    assert under_chaos >= 0.7 * steady, \
        f"chaos throughput {under_chaos:.0f} < 70% of steady {steady:.0f}"


def test_threaded_chaos_conservation_and_clean_lockset():
    """The standard chaos scenario driven by real worker threads
    (run_parallel(threads=True)): backend reset at 25%, worker kill at
    50%, policy hot-swap at 75% of the epoch budget. Every delivered
    frame is one of the originals (exactly once), delivered + counted
    drops == sent on every channel, the LocksetMonitor observes zero
    unlocked cross-worker mutations from the real threads, and shutdown
    proves zero leaked pages/grant pins. Fault `at=` times are in EPOCH
    units under the threaded executor (the plan ticks once per epoch
    barrier, not once per scheduler round)."""
    from repro.analysis import lockset

    epochs = 8
    cl = LibraCluster(3, secret=b"chaos", **STACK_KW)
    health = HealthTable(2, fail_threshold=2)
    plan = FaultPlan(seed=13)
    crt = ClusterRuntime(cl, policy=_fo_table(health), fault_plan=plan)
    plan.reset(0, at=epochs // 4)
    plan.kill_worker(2, at=epochs // 2)

    def swap_all(rt):
        for t in rt.policies:
            if t is not None:
                t.swap([rule(forward(0, failover=1), eq(TAG, 7))])
    plan.at(3 * epochs // 4, swap_all)

    chans, dst_pairs, sent = [], [], []
    for i in range(6):
        src = cl.socket(worker=i % 3)
        pair = [cl.socket(worker=(i + 1) % 3) for _ in range(2)]
        chans.append(crt.channel(src, pair))
        dst_pairs.append(pair)
        sent.append(_deliver(src, 4, seed=300 + i))

    with lockset.LocksetMonitor(cl) as mon:
        msgs, times = crt.run_parallel(threads=True, epoch_rounds=64)
    assert mon.violations == [], mon.format()
    assert cl.stats["worker_kills"] == 1
    assert all(t is None or t.epoch == 1 for t in crt.policies
               if t is not None)
    assert len(times) == 3 and all(t >= 0 for t in times)

    for i, (d0, d1) in enumerate(dst_pairs):
        got = sorted(_frames_of(d0.tx_wire()) + _frames_of(d1.tx_wire()))
        exp = sorted(tuple(int(x) for x in f) for f in sent[i])
        assert len(got) == len(set(got))
        assert set(got) <= set(exp), f"channel {i} delivered foreign bytes"
        drops = chans[i].stats.timeouts + chans[i].stats.drops
        assert len(got) + drops == len(exp), \
            f"channel {i}: {len(exp) - len(got) - drops} uncounted losses"
    crt.shutdown()         # asserts zero leaked pages/grants on every pool
