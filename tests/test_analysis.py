"""Datapath verifier (repro.analysis): per-rule positive/negative fixtures
for the ownership lint, jaxpr audit, and lockset checker; the waiver
machinery; the runtime lockset monitor against real cluster runs; and
regression tests for the fault-path leaks the ownership lint caught in
core/ (each reproduced by monkeypatched faults, asserting the pool and
grant pins are restored)."""
import numpy as np
import pytest

from repro.analysis import (
    concurrency,
    importgraph,
    jaxpr_audit,
    lockset,
    ownership,
)
from repro.analysis.common import Finding, build_report
from repro.analysis.ownership import OWNERSHIP_RULES, lint_source
from repro.core import (
    ClusterRuntime,
    LibraCluster,
    LibraStack,
    build_message,
)

RNG = np.random.default_rng(7)

STACK_KW = dict(n_shards=4, pages_per_shard=128, page_size=16)


def _rules(findings):
    return [f.rule for f in findings]


def _report(source):
    return build_report("ownership", lint_source(source, "fix.py"),
                        {"fix.py": source}, rules=OWNERSHIP_RULES)


# ---------------------------------------------------------------------------
# ownership lint: rule fixtures
# ---------------------------------------------------------------------------

def test_own001_risky_call_between_acquire_and_handoff():
    src = '''
def f(pool, payload):
    pages = pool.alloc.alloc_page(4)
    pool.write_payload(pages, payload)
    return registry.register(pages)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN001"]


def test_own001_raise_while_holding():
    src = '''
def f(pool, cond):
    pages = pool.alloc.alloc_page(4)
    if cond:
        raise RuntimeError("x")
    pool.alloc.free_pages_list(pages)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN001"]


def test_own001_emptiness_guard_exempts_raise():
    # `if not pages: raise` proves nothing is held on the raising path
    src = '''
def f(pool):
    pages = pool.alloc.alloc_page(4)
    if not pages:
        raise RuntimeError("x")
    pool.alloc.free_pages_list(pages)
'''
    assert lint_source(src, "fix.py") == []


def test_own001_clean_with_try_finally():
    src = '''
def f(pool, payload):
    pages = pool.alloc.alloc_page(4)
    try:
        pool.write_payload(pages, payload)
    finally:
        pool.alloc.free_pages_list(pages)
'''
    assert lint_source(src, "fix.py") == []


def test_own001_clean_with_except_cleanup_then_handoff():
    src = '''
def f(pool, registry, payload):
    pages = pool.alloc.alloc_page(4)
    try:
        pool.write_payload(pages, payload)
        vpi = registry.register(pool.pool_id, pages, 4)
    except BaseException:
        pool.alloc.free_pages_list(pages)
        raise
    return vpi
'''
    assert lint_source(src, "fix.py") == []


def test_own002_discarded_acquire():
    src = '''
def f(pool):
    pool.alloc.alloc_page(4)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN002"]


def test_own003_early_return_while_holding():
    src = '''
def f(pool, cond):
    pages = pool.alloc.alloc_page(4)
    if cond:
        return None
    pool.alloc.free_pages_list(pages)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN003"]


def test_own004_rebind_without_release():
    src = '''
def f(pool):
    pages = pool.alloc.alloc_page(4)
    try:
        pages = pool.alloc.alloc_page(8)
    finally:
        pool.alloc.free_pages_list(pages)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN004"]


def test_handoff_to_registry_is_a_release():
    src = '''
def f(pool, registry):
    pages = pool.alloc.alloc_page(4)
    return registry.register(pool.pool_id, pages, 4)
'''
    assert lint_source(src, "fix.py") == []


def test_bare_pin_released_via_reconstructed_refs():
    # export_grant() binds no name; release_export on reconstructed
    # PageRefs is the only possible release and must satisfy the lint
    src = '''
def f(owner, pages, dst, vpi):
    owner.alloc.export_grant([PageRef(*p) for p in pages])
    try:
        return dst.registry.import_grant(owner.registry, vpi, 1, pages, 4)
    except BaseException:
        owner.alloc.release_export([PageRef(*p) for p in pages])
        raise
'''
    assert lint_source(src, "fix.py") == []


# ---------------------------------------------------------------------------
# waiver machinery
# ---------------------------------------------------------------------------

WAIVED_SRC = '''
def f(pool, payload):
    pages = pool.alloc.alloc_page(4)
    pool.write_payload(pages, payload)  # libra: waive[OWN001] freed by caller
    return registry.register(pages)
'''


def test_waiver_with_reason_moves_finding_to_waived():
    rep = _report(WAIVED_SRC)
    assert rep.ok
    assert _rules(rep.waived) == ["OWN001"]
    assert rep.waived[0].waiver_reason == "freed by caller"


def test_waiver_without_reason_is_its_own_finding():
    rep = _report(WAIVED_SRC.replace(" freed by caller", ""))
    assert _rules(rep.active) == ["WAIVER001"]


def test_stale_waiver_is_flagged():
    src = '''
def f(pool):
    pages = pool.alloc.alloc_page(4)  # libra: waive[OWN001] nothing raises
    pool.alloc.free_pages_list(pages)
'''
    rep = _report(src)
    assert _rules(rep.active) == ["WAIVER002"]


# ---------------------------------------------------------------------------
# jaxpr audit fixtures
# ---------------------------------------------------------------------------

def test_jaxpr_smuggled_concatenate_is_flagged():
    import jax.numpy as jnp

    def smuggled(a, b):
        return jnp.concatenate([a, b])

    x = jnp.zeros((4,), jnp.int32)
    findings = jaxpr_audit.audit_fn(smuggled, (x, x), name="smuggled",
                                    n_pallas=0)
    assert "JAX002" in _rules(findings)


def test_jaxpr_pallas_count_regression_is_flagged():
    import jax.numpy as jnp

    def plain(a):
        return a + 1

    findings = jaxpr_audit.audit_fn(plain, (jnp.zeros((4,), jnp.int32),),
                                    name="plain", n_pallas=1)
    assert "JAX001" in _rules(findings)


def test_jaxpr_boundary_budget_mismatch_is_flagged():
    import jax.numpy as jnp

    def plain(a):
        return a * 2

    x = jnp.zeros((8,), jnp.int32)
    ok = jaxpr_audit.audit_fn(plain, (x,), name="b", n_pallas=0,
                              declared_boundary=16)
    bad = jaxpr_audit.audit_fn(plain, (x,), name="b", n_pallas=0,
                               declared_boundary=17)
    assert ok == []
    assert _rules(bad) == ["JAX004"]


def test_jaxpr_clean_fn_passes():
    import jax.numpy as jnp

    def clean(a):
        return a + 1

    assert jaxpr_audit.audit_fn(clean, (jnp.zeros((4,), jnp.int32),),
                                name="clean", n_pallas=0) == []


# ---------------------------------------------------------------------------
# lockset checker: synthetic fixtures
# ---------------------------------------------------------------------------

SYNTH_CLUSTER = '''
class SteeringPolicy:
    def __init__(self):
        self.placements = {}
    def worker_for(self, key):
        self.placements[key] = 0
        return 0

class LibraCluster:
    def __init__(self):
        self.workers = []

    def bad_grant(self, dst_stack, vpi):
        dst_stack.registry.import_grant(None, vpi, 0, [], 0)

    def good_grant(self, dst_stack, vpi):
        with self.lock:
            return self._good_locked(dst_stack, vpi)

    def _good_locked(self, dst_stack, vpi):
        return dst_stack.registry.import_grant(None, vpi, 0, [], 0)

    def bad_caller(self, dst_stack, vpi):
        return self._good_locked(dst_stack, vpi)
'''


@pytest.fixture
def synth_root(tmp_path):
    core = tmp_path / "src" / "repro" / "core"
    core.mkdir(parents=True)
    (core / "cluster.py").write_text(SYNTH_CLUSTER)
    (core / "egress.py").write_text("")
    (core / "stack.py").write_text("")
    (core / "anchor_pool.py").write_text("class AnchorPool:\n    pass\n")
    (core / "vpi.py").write_text("class VpiRegistry:\n    pass\n")
    (core / "policy.py").write_text(
        "class HealthTable:\n"
        "    def __init__(self):\n"
        "        self.state = {}\n")
    return tmp_path


def test_lock001_unlocked_peer_mutation_and_unlocked_locked_call(synth_root):
    sites, findings = lockset.derive_sites(synth_root)
    # both the locked and unlocked grant sites are in the manifest...
    assert {(s["func"], s["path"]) for s in sites} == {
        ("LibraCluster.bad_grant", "dst_stack.registry.import_grant"),
        ("LibraCluster._good_locked", "dst_stack.registry.import_grant"),
    }
    # ...but only the unlocked one, plus the unlocked *_locked call, fail
    assert sorted((f.rule, f.message.split(":")[0]) for f in findings) == [
        ("LOCK001", "LibraCluster.bad_caller"),
        ("LOCK001", "LibraCluster.bad_grant"),
    ]


def test_lock003_missing_lock_plumbing(synth_root):
    msgs = [f.message for f in lockset.check_plumbing(synth_root)]
    assert any("SteeringPolicy.__init__" in m for m in msgs)
    assert any("HealthTable.__init__" in m for m in msgs)
    assert any("worker's alloc" in m for m in msgs)
    assert any("worker's registry" in m for m in msgs)


def test_lock002_manifest_drift():
    derived = {"classes": {"AnchorPool": ["_free", "stats"]},
               "sites": [{"file": "a.py", "func": "f", "path": "p.q",
                          "kind": "call"}]}
    committed = {"classes": {"AnchorPool": ["_free"]}, "sites": []}
    findings = lockset.compare_manifest(derived, committed)
    assert _rules(findings) == ["LOCK002", "LOCK002"]
    assert "stats" in findings[0].message
    assert lockset.compare_manifest(derived, derived) == []


# ---------------------------------------------------------------------------
# the real tree passes all three gates
# ---------------------------------------------------------------------------

def test_real_tree_ownership_clean():
    rep = ownership.run()
    assert rep.ok, "\n".join(rep.lines())


def test_real_tree_lockset_clean_and_manifest_current():
    rep = lockset.run()
    assert rep.ok, "\n".join(rep.lines())


def test_import_graph_reaches_core():
    dead = importgraph.unreachable()
    assert "repro.core.stack" not in dead
    assert "repro.core.cluster" not in dead
    assert "repro.analysis.lockset" not in dead  # this test imports it


def test_cli_runs_selected_pass():
    from repro.analysis.__main__ import main
    assert main(["--pass", "ownership"]) == 0


# ---------------------------------------------------------------------------
# runtime lockset monitor
# ---------------------------------------------------------------------------

def _cluster(n_workers=2):
    return LibraCluster(n_workers, secret=b"an", **STACK_KW)


def _frames(n_chans, n_msgs=4, seed=11):
    rng = np.random.default_rng(seed)
    return [[build_message(rng.integers(100, 200, 4),
                           rng.integers(1000, 2000, 40))
             for _ in range(n_msgs)]
            for _ in range(n_chans)]


def test_monitor_clean_on_locked_cross_worker_grants():
    """Cross-worker flows (grants, owner-pool egress) with stealing off:
    every cross-worker mutation runs under the plane lock, so the monitor
    sees shared objects but zero violations."""
    cl = _cluster(2)
    crt = ClusterRuntime(cl, work_stealing=False)
    for i, chan in enumerate(_frames(8)):
        sw = i % 2
        dw = (sw + 1) % 2 if i < 4 else sw
        src, dst = cl.socket(worker=sw), cl.socket(worker=dw)
        crt.channel(src, dst)
        for f in chan:
            src.deliver(f)
    with lockset.LocksetMonitor(cl) as mon:
        crt.run()
    assert mon.violations == [], mon.format()
    # the grant protocol really did touch both registries from both sides
    assert "worker0.registry" in mon.shared_objects() \
        or "worker1.registry" in mon.shared_objects()
    crt.shutdown()
    assert cl.pages_in_use == 0


def test_monitor_clean_on_work_stealing():
    """All flows pinned to worker 0 with stealing on: worker 1's scheduler
    quantum runs worker 0's channels under steal-under-lock — the thief
    holds the plane lock for the whole stolen quantum, so the monitor
    attributes every donor-state mutation with no by-design carve-out.
    (Before owner-pinned steal queues this scenario was the one designed
    LOCK004 source; it must now run clean, like every other path.)"""
    cl = _cluster(2)
    crt = ClusterRuntime(cl, work_stealing=True)
    for chan in _frames(8):
        src, dst = cl.socket(worker=0), cl.socket(worker=0)
        crt.channel(src, dst)
        for f in chan:
            src.deliver(f)
    with lockset.LocksetMonitor(cl) as mon:
        crt.run()
    assert crt.stats["stolen_quanta"] > 0, \
        "scenario must actually exercise stealing"
    assert mon.violations == [], mon.format()
    crt.shutdown()


def test_monitor_uninstalls_cleanly():
    cl = _cluster(2)
    with lockset.LocksetMonitor(cl):
        assert "alloc_page" in vars(cl.workers[0].alloc)
    for w in cl.workers:
        assert "alloc_page" not in vars(w.alloc)
        assert "register" not in vars(w.registry)


# ---------------------------------------------------------------------------
# regression: the fault-path leaks the ownership lint caught in core/
# ---------------------------------------------------------------------------

def _stack():
    return LibraStack(secret=b"an", **STACK_KW)


def test_ingress_write_payload_fault_returns_pages_to_pool(monkeypatch):
    """ingress WRITE_VPI: a fault while anchoring (between alloc and
    registry handoff) must hand the pages back, not leak them."""
    stack = _stack()
    src = stack.socket()
    src.deliver(build_message(RNG.integers(100, 200, 4),
                              RNG.integers(1000, 2000, 40)))

    def boom(*a, **kw):
        raise RuntimeError("injected anchoring fault")

    monkeypatch.setattr(stack.pool, "write_payload", boom)
    with pytest.raises(RuntimeError, match="injected"):
        src.recv(1 << 20)
    assert stack.alloc.free_pages == stack.alloc.total_pages
    assert len(stack.registry) == 0


def test_recv_batch_crypto_fault_frees_whole_round(monkeypatch):
    """stack recv_batch: a fault mid-round (vectorized keystream sweep)
    must free every page list the round still owns."""
    stack = _stack()
    socks = []
    for _ in range(3):
        s = stack.socket("length-prefixed", tls="hw")
        frame = build_message(RNG.integers(100, 200, 4),
                              RNG.integers(1000, 2000, 40))
        s.deliver(s.tls.seal(frame, s.parser.inner))
        socks.append(s)

    def boom(*a, **kw):
        raise RuntimeError("injected crypto fault")

    monkeypatch.setattr("repro.core.stack.keystream_batch", boom)
    with pytest.raises(RuntimeError, match="injected"):
        stack.recv_batch(socks, 1 << 20)
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_grant_into_import_fault_releases_export_pin(monkeypatch):
    """cluster grant_into: a fault in the destination's import_grant must
    release the owner-side export pin, or the owner's pages stay pinned
    forever (no grantee exists to ever complete)."""
    cl = _cluster(2)
    w0, w1 = cl.workers
    src = cl.socket(worker=0)
    src.deliver(build_message(RNG.integers(100, 200, 4),
                              RNG.integers(1000, 2000, 40)))
    src.recv(1 << 20)
    vpi = next(iter(src.connection.anchored))
    assert w0.pages_in_use > 0

    def boom(*a, **kw):
        raise RuntimeError("injected import fault")

    monkeypatch.setattr(w1.registry, "import_grant", boom)
    with pytest.raises(RuntimeError, match="injected"):
        cl.grant_into(w1, vpi)
    assert w0.alloc.granted_out_pages == 0
    assert not cl.lock.held            # the with-statement unwound the lock
    # the anchor is still intact and grantable once the fault clears
    monkeypatch.undo()
    assert cl.grant_into(w1, vpi) is not None
    assert cl.stats["grants"] == 1


# ---------------------------------------------------------------------------
# concurrency verifier: lock-order / deadlock fixtures
# ---------------------------------------------------------------------------

def _conc_scan(src):
    """All three concurrency scanners over one synthetic plane file."""
    sources = {"src/repro/core/cluster.py": src}
    edges, findings = concurrency.derive_lock_graph(sources)
    findings += concurrency.check_lock_order(edges)
    findings += concurrency.scan_atomicity(sources)
    findings += concurrency.scan_steal(sources)
    return findings


def test_dead001_opposing_acquisition_orders_are_a_cycle():
    src = '''
def fwd(dst_stack):
    with plane_lock(dst_stack.registry):
        with plane_lock(dst_stack.alloc):
            dst_stack.alloc.free_pages_list([])

def rev(dst_stack):
    with plane_lock(dst_stack.alloc):
        with plane_lock(dst_stack.registry):
            dst_stack.registry.release(0)
'''
    rules = set(_rules(_conc_scan(src)))
    # the reversed nesting is both a rank inversion and a static deadlock
    assert "DEAD001" in rules and "DEAD002" in rules


def test_dead002_rank_inversion_without_cycle():
    src = '''
def bad(self, pool):
    with plane_lock(pool.alloc):
        with self.cluster.lock:
            self.cluster.stats["x"] = 1
'''
    assert _rules(_conc_scan(src)) == ["DEAD002"]


def test_dead003_unclassifiable_lock():
    src = '''
def bad(self, mystery):
    with plane_lock(mystery):
        mystery.release(0)
'''
    assert _rules(_conc_scan(src)) == ["DEAD003"]


def test_dead_clean_on_ordered_and_reentrant_nesting():
    src = '''
def good(self, dst_stack):
    with self.cluster.lock:
        with plane_lock(dst_stack.registry):
            with plane_lock(dst_stack.alloc):
                dst_stack.alloc.free_pages_list([])

def reentrant(self, dst_stack):
    with plane_lock(dst_stack.registry):
        with plane_lock(dst_stack.registry):
            dst_stack.registry.release(0)
'''
    assert _conc_scan(src) == []


def test_dead_locked_function_holds_plane_from_entry():
    # a *_locked body acquiring a leaf is a plane->steering edge, in order
    src = '''
def _kill_locked(self, dst_stack):
    self.steering.remove_worker(0)
'''
    sources = {"src/repro/core/cluster.py": src}
    edges, findings = concurrency.derive_lock_graph(sources)
    assert findings == []
    assert {(e["src"], e["dst"]) for e in edges} == {("plane", "steering")}
    assert concurrency.check_lock_order(edges) == []


def test_dead003_hierarchy_manifest_drift():
    base = {"version": 1, "ranks": dict(concurrency.LOCK_RANKS),
            "edges": [{"src": "plane", "dst": "steering",
                       "file": "a.py", "func": "f"}]}
    assert concurrency.compare_hierarchy(base, base) == []
    missing = concurrency.compare_hierarchy(base, None)
    assert _rules(missing) == ["DEAD003"] and "missing" in missing[0].message
    grown = {**base, "edges": base["edges"] + [
        {"src": "registry", "dst": "alloc", "file": "b.py", "func": "g"}]}
    new = concurrency.compare_hierarchy(grown, base)
    assert _rules(new) == ["DEAD003"] and "new lock-order edge" in new[0].message
    gone = concurrency.compare_hierarchy(base, grown)
    assert _rules(gone) == ["DEAD003"] and "no longer exists" in gone[0].message


# ---------------------------------------------------------------------------
# concurrency verifier: atomicity fixtures
# ---------------------------------------------------------------------------

def test_atom001_unlocked_check_then_act_on_peer_state():
    src = '''
def bad(self, dst_stack, vpi):
    if dst_stack.registry.peek(vpi) is not None:
        dst_stack.registry.release(vpi)
'''
    assert _rules(_conc_scan(src)) == ["ATOM001"]


def test_atom001_clean_when_region_shares_one_lock_scope():
    src = '''
def good(self, dst_stack, vpi):
    with plane_lock(dst_stack.registry):
        if dst_stack.registry.peek(vpi) is not None:
            dst_stack.registry.release(vpi)
'''
    assert _conc_scan(src) == []


def test_atom002_unlocked_rmw_on_allocator_state():
    src = '''
def bad(self, pool, n):
    pool.alloc.accounted_pages += n
'''
    assert _rules(_conc_scan(src)) == ["ATOM002"]


def test_atom002_clean_under_lock():
    src = '''
def good(self, pool, n):
    with plane_lock(pool.alloc):
        pool.alloc.accounted_pages += n
'''
    assert _conc_scan(src) == []


def test_atom003_guard_result_crosses_fragmented_lock_scopes():
    src = '''
def bad(self, dst_stack, vpi):
    with plane_lock(dst_stack.registry):
        entry = dst_stack.registry.peek(vpi)
    with plane_lock(dst_stack.registry):
        dst_stack.registry.release(entry)
'''
    assert _rules(_conc_scan(src)) == ["ATOM003"]


def test_atom003_clean_in_one_continuous_scope():
    src = '''
def good(self, dst_stack, vpi):
    with plane_lock(dst_stack.registry):
        entry = dst_stack.registry.peek(vpi)
        dst_stack.registry.release(entry)
'''
    assert _conc_scan(src) == []


# ---------------------------------------------------------------------------
# concurrency verifier: steal-path fixtures
# ---------------------------------------------------------------------------

def test_steal001_stolen_quantum_serviced_without_lock():
    src = '''
def bad(self):
    for i, rt in enumerate(self.runtimes):
        for ch in rt.poll():
            with self.cluster.as_worker(i):
                ch.service()
'''
    assert _rules(_conc_scan(src)) == ["STEAL001"]


def test_steal001_clean_under_cluster_lock():
    src = '''
def good(self):
    for i, rt in enumerate(self.runtimes):
        for ch in rt.poll():
            with self.cluster.lock:
                with self.cluster.as_worker(i):
                    ch.service()
'''
    assert _conc_scan(src) == []


def test_steal002_stolen_reference_escapes_into_attribute():
    src = '''
def bad(self, rt):
    take = list(rt.poll())
    for ch in take:
        self.backlog.append(ch)
    self.pending = take
'''
    assert _rules(_conc_scan(src)) == ["STEAL002", "STEAL002"]


def test_steal002_local_bookkeeping_containers_allowed():
    src = '''
def good(self, rt):
    stolen = set()
    take = list(rt.poll())
    for ch in take:
        stolen.add(ch)
    return stolen
'''
    assert _conc_scan(src) == []


# ---------------------------------------------------------------------------
# the real tree passes the concurrency and import gates
# ---------------------------------------------------------------------------

def test_real_tree_concurrency_clean_and_manifest_current():
    rep = concurrency.run()
    assert rep.ok, "\n".join(rep.lines())


def test_real_tree_lock_graph_is_exactly_the_committed_hierarchy():
    sources = {rel: (concurrency.REPO_ROOT / rel).read_text()
               for rel in concurrency.CONCURRENCY_FILES}
    edges, findings = concurrency.derive_lock_graph(sources)
    assert findings == []
    assert {(e["src"], e["dst"]) for e in edges} == {
        ("plane", "steering"), ("registry", "alloc")}


def test_real_tree_imports_gated_clean():
    rep = importgraph.run()
    assert rep.ok, "\n".join(rep.lines())
