"""Datapath verifier (repro.analysis): per-rule positive/negative fixtures
for the ownership lint, jaxpr audit, and lockset checker; the waiver
machinery; the runtime lockset monitor against real cluster runs; and
regression tests for the fault-path leaks the ownership lint caught in
core/ (each reproduced by monkeypatched faults, asserting the pool and
grant pins are restored)."""
import numpy as np
import pytest

from repro.analysis import importgraph, jaxpr_audit, lockset, ownership
from repro.analysis.common import Finding, build_report
from repro.analysis.ownership import OWNERSHIP_RULES, lint_source
from repro.core import (
    ClusterRuntime,
    LibraCluster,
    LibraStack,
    build_message,
)

RNG = np.random.default_rng(7)

STACK_KW = dict(n_shards=4, pages_per_shard=128, page_size=16)


def _rules(findings):
    return [f.rule for f in findings]


def _report(source):
    return build_report("ownership", lint_source(source, "fix.py"),
                        {"fix.py": source}, rules=OWNERSHIP_RULES)


# ---------------------------------------------------------------------------
# ownership lint: rule fixtures
# ---------------------------------------------------------------------------

def test_own001_risky_call_between_acquire_and_handoff():
    src = '''
def f(pool, payload):
    pages = pool.alloc.alloc_page(4)
    pool.write_payload(pages, payload)
    return registry.register(pages)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN001"]


def test_own001_raise_while_holding():
    src = '''
def f(pool, cond):
    pages = pool.alloc.alloc_page(4)
    if cond:
        raise RuntimeError("x")
    pool.alloc.free_pages_list(pages)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN001"]


def test_own001_emptiness_guard_exempts_raise():
    # `if not pages: raise` proves nothing is held on the raising path
    src = '''
def f(pool):
    pages = pool.alloc.alloc_page(4)
    if not pages:
        raise RuntimeError("x")
    pool.alloc.free_pages_list(pages)
'''
    assert lint_source(src, "fix.py") == []


def test_own001_clean_with_try_finally():
    src = '''
def f(pool, payload):
    pages = pool.alloc.alloc_page(4)
    try:
        pool.write_payload(pages, payload)
    finally:
        pool.alloc.free_pages_list(pages)
'''
    assert lint_source(src, "fix.py") == []


def test_own001_clean_with_except_cleanup_then_handoff():
    src = '''
def f(pool, registry, payload):
    pages = pool.alloc.alloc_page(4)
    try:
        pool.write_payload(pages, payload)
        vpi = registry.register(pool.pool_id, pages, 4)
    except BaseException:
        pool.alloc.free_pages_list(pages)
        raise
    return vpi
'''
    assert lint_source(src, "fix.py") == []


def test_own002_discarded_acquire():
    src = '''
def f(pool):
    pool.alloc.alloc_page(4)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN002"]


def test_own003_early_return_while_holding():
    src = '''
def f(pool, cond):
    pages = pool.alloc.alloc_page(4)
    if cond:
        return None
    pool.alloc.free_pages_list(pages)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN003"]


def test_own004_rebind_without_release():
    src = '''
def f(pool):
    pages = pool.alloc.alloc_page(4)
    try:
        pages = pool.alloc.alloc_page(8)
    finally:
        pool.alloc.free_pages_list(pages)
'''
    assert _rules(lint_source(src, "fix.py")) == ["OWN004"]


def test_handoff_to_registry_is_a_release():
    src = '''
def f(pool, registry):
    pages = pool.alloc.alloc_page(4)
    return registry.register(pool.pool_id, pages, 4)
'''
    assert lint_source(src, "fix.py") == []


def test_bare_pin_released_via_reconstructed_refs():
    # export_grant() binds no name; release_export on reconstructed
    # PageRefs is the only possible release and must satisfy the lint
    src = '''
def f(owner, pages, dst, vpi):
    owner.alloc.export_grant([PageRef(*p) for p in pages])
    try:
        return dst.registry.import_grant(owner.registry, vpi, 1, pages, 4)
    except BaseException:
        owner.alloc.release_export([PageRef(*p) for p in pages])
        raise
'''
    assert lint_source(src, "fix.py") == []


# ---------------------------------------------------------------------------
# waiver machinery
# ---------------------------------------------------------------------------

WAIVED_SRC = '''
def f(pool, payload):
    pages = pool.alloc.alloc_page(4)
    pool.write_payload(pages, payload)  # libra: waive[OWN001] freed by caller
    return registry.register(pages)
'''


def test_waiver_with_reason_moves_finding_to_waived():
    rep = _report(WAIVED_SRC)
    assert rep.ok
    assert _rules(rep.waived) == ["OWN001"]
    assert rep.waived[0].waiver_reason == "freed by caller"


def test_waiver_without_reason_is_its_own_finding():
    rep = _report(WAIVED_SRC.replace(" freed by caller", ""))
    assert _rules(rep.active) == ["WAIVER001"]


def test_stale_waiver_is_flagged():
    src = '''
def f(pool):
    pages = pool.alloc.alloc_page(4)  # libra: waive[OWN001] nothing raises
    pool.alloc.free_pages_list(pages)
'''
    rep = _report(src)
    assert _rules(rep.active) == ["WAIVER002"]


# ---------------------------------------------------------------------------
# jaxpr audit fixtures
# ---------------------------------------------------------------------------

def test_jaxpr_smuggled_concatenate_is_flagged():
    import jax.numpy as jnp

    def smuggled(a, b):
        return jnp.concatenate([a, b])

    x = jnp.zeros((4,), jnp.int32)
    findings = jaxpr_audit.audit_fn(smuggled, (x, x), name="smuggled",
                                    n_pallas=0)
    assert "JAX002" in _rules(findings)


def test_jaxpr_pallas_count_regression_is_flagged():
    import jax.numpy as jnp

    def plain(a):
        return a + 1

    findings = jaxpr_audit.audit_fn(plain, (jnp.zeros((4,), jnp.int32),),
                                    name="plain", n_pallas=1)
    assert "JAX001" in _rules(findings)


def test_jaxpr_boundary_budget_mismatch_is_flagged():
    import jax.numpy as jnp

    def plain(a):
        return a * 2

    x = jnp.zeros((8,), jnp.int32)
    ok = jaxpr_audit.audit_fn(plain, (x,), name="b", n_pallas=0,
                              declared_boundary=16)
    bad = jaxpr_audit.audit_fn(plain, (x,), name="b", n_pallas=0,
                               declared_boundary=17)
    assert ok == []
    assert _rules(bad) == ["JAX004"]


def test_jaxpr_clean_fn_passes():
    import jax.numpy as jnp

    def clean(a):
        return a + 1

    assert jaxpr_audit.audit_fn(clean, (jnp.zeros((4,), jnp.int32),),
                                name="clean", n_pallas=0) == []


# ---------------------------------------------------------------------------
# lockset checker: synthetic fixtures
# ---------------------------------------------------------------------------

SYNTH_CLUSTER = '''
class SteeringPolicy:
    def __init__(self):
        self.placements = {}
    def worker_for(self, key):
        self.placements[key] = 0
        return 0

class LibraCluster:
    def __init__(self):
        self.workers = []

    def bad_grant(self, dst_stack, vpi):
        dst_stack.registry.import_grant(None, vpi, 0, [], 0)

    def good_grant(self, dst_stack, vpi):
        with self.lock:
            return self._good_locked(dst_stack, vpi)

    def _good_locked(self, dst_stack, vpi):
        return dst_stack.registry.import_grant(None, vpi, 0, [], 0)

    def bad_caller(self, dst_stack, vpi):
        return self._good_locked(dst_stack, vpi)
'''


@pytest.fixture
def synth_root(tmp_path):
    core = tmp_path / "src" / "repro" / "core"
    core.mkdir(parents=True)
    (core / "cluster.py").write_text(SYNTH_CLUSTER)
    (core / "egress.py").write_text("")
    (core / "stack.py").write_text("")
    (core / "anchor_pool.py").write_text("class AnchorPool:\n    pass\n")
    (core / "vpi.py").write_text("class VpiRegistry:\n    pass\n")
    (core / "policy.py").write_text(
        "class HealthTable:\n"
        "    def __init__(self):\n"
        "        self.state = {}\n")
    return tmp_path


def test_lock001_unlocked_peer_mutation_and_unlocked_locked_call(synth_root):
    sites, findings = lockset.derive_sites(synth_root)
    # both the locked and unlocked grant sites are in the manifest...
    assert {(s["func"], s["path"]) for s in sites} == {
        ("LibraCluster.bad_grant", "dst_stack.registry.import_grant"),
        ("LibraCluster._good_locked", "dst_stack.registry.import_grant"),
    }
    # ...but only the unlocked one, plus the unlocked *_locked call, fail
    assert sorted((f.rule, f.message.split(":")[0]) for f in findings) == [
        ("LOCK001", "LibraCluster.bad_caller"),
        ("LOCK001", "LibraCluster.bad_grant"),
    ]


def test_lock003_missing_lock_plumbing(synth_root):
    msgs = [f.message for f in lockset.check_plumbing(synth_root)]
    assert any("SteeringPolicy.__init__" in m for m in msgs)
    assert any("HealthTable.__init__" in m for m in msgs)
    assert any("worker's alloc" in m for m in msgs)
    assert any("worker's registry" in m for m in msgs)


def test_lock002_manifest_drift():
    derived = {"classes": {"AnchorPool": ["_free", "stats"]},
               "sites": [{"file": "a.py", "func": "f", "path": "p.q",
                          "kind": "call"}]}
    committed = {"classes": {"AnchorPool": ["_free"]}, "sites": []}
    findings = lockset.compare_manifest(derived, committed)
    assert _rules(findings) == ["LOCK002", "LOCK002"]
    assert "stats" in findings[0].message
    assert lockset.compare_manifest(derived, derived) == []


# ---------------------------------------------------------------------------
# the real tree passes all three gates
# ---------------------------------------------------------------------------

def test_real_tree_ownership_clean():
    rep = ownership.run()
    assert rep.ok, "\n".join(rep.lines())


def test_real_tree_lockset_clean_and_manifest_current():
    rep = lockset.run()
    assert rep.ok, "\n".join(rep.lines())


def test_import_graph_reaches_core():
    dead = importgraph.unreachable()
    assert "repro.core.stack" not in dead
    assert "repro.core.cluster" not in dead
    assert "repro.analysis.lockset" not in dead  # this test imports it


def test_cli_runs_selected_pass():
    from repro.analysis.__main__ import main
    assert main(["--pass", "ownership"]) == 0


# ---------------------------------------------------------------------------
# runtime lockset monitor
# ---------------------------------------------------------------------------

def _cluster(n_workers=2):
    return LibraCluster(n_workers, secret=b"an", **STACK_KW)


def _frames(n_chans, n_msgs=4, seed=11):
    rng = np.random.default_rng(seed)
    return [[build_message(rng.integers(100, 200, 4),
                           rng.integers(1000, 2000, 40))
             for _ in range(n_msgs)]
            for _ in range(n_chans)]


def test_monitor_clean_on_locked_cross_worker_grants():
    """Cross-worker flows (grants, owner-pool egress) with stealing off:
    every cross-worker mutation runs under the plane lock, so the monitor
    sees shared objects but zero violations."""
    cl = _cluster(2)
    crt = ClusterRuntime(cl, work_stealing=False)
    for i, chan in enumerate(_frames(8)):
        sw = i % 2
        dw = (sw + 1) % 2 if i < 4 else sw
        src, dst = cl.socket(worker=sw), cl.socket(worker=dw)
        crt.channel(src, dst)
        for f in chan:
            src.deliver(f)
    with lockset.LocksetMonitor(cl) as mon:
        crt.run()
    assert mon.violations == [], mon.format()
    # the grant protocol really did touch both registries from both sides
    assert "worker0.registry" in mon.shared_objects() \
        or "worker1.registry" in mon.shared_objects()
    crt.shutdown()
    assert cl.pages_in_use == 0


def test_monitor_flags_work_stealing_as_unsynchronized():
    """All flows pinned to worker 0 with stealing on: worker 1's scheduler
    quantum runs worker 0's channels, mutating worker 0's allocator and
    registry from the thief's context without the plane lock — exactly the
    hazard the threaded-executor readiness gate must catch."""
    cl = _cluster(2)
    crt = ClusterRuntime(cl, work_stealing=True)
    for chan in _frames(8):
        src, dst = cl.socket(worker=0), cl.socket(worker=0)
        crt.channel(src, dst)
        for f in chan:
            src.deliver(f)
    with lockset.LocksetMonitor(cl) as mon:
        crt.run()
    assert mon.violations, "stealing should trip the lockset monitor"
    assert all(f.rule == "LOCK004" for f in mon.violations)
    assert any("worker 1's context" in f.message for f in mon.violations)
    crt.shutdown()


def test_monitor_uninstalls_cleanly():
    cl = _cluster(2)
    with lockset.LocksetMonitor(cl):
        assert "alloc_page" in vars(cl.workers[0].alloc)
    for w in cl.workers:
        assert "alloc_page" not in vars(w.alloc)
        assert "register" not in vars(w.registry)


# ---------------------------------------------------------------------------
# regression: the fault-path leaks the ownership lint caught in core/
# ---------------------------------------------------------------------------

def _stack():
    return LibraStack(secret=b"an", **STACK_KW)


def test_ingress_write_payload_fault_returns_pages_to_pool(monkeypatch):
    """ingress WRITE_VPI: a fault while anchoring (between alloc and
    registry handoff) must hand the pages back, not leak them."""
    stack = _stack()
    src = stack.socket()
    src.deliver(build_message(RNG.integers(100, 200, 4),
                              RNG.integers(1000, 2000, 40)))

    def boom(*a, **kw):
        raise RuntimeError("injected anchoring fault")

    monkeypatch.setattr(stack.pool, "write_payload", boom)
    with pytest.raises(RuntimeError, match="injected"):
        src.recv(1 << 20)
    assert stack.alloc.free_pages == stack.alloc.total_pages
    assert len(stack.registry) == 0


def test_recv_batch_crypto_fault_frees_whole_round(monkeypatch):
    """stack recv_batch: a fault mid-round (vectorized keystream sweep)
    must free every page list the round still owns."""
    stack = _stack()
    socks = []
    for _ in range(3):
        s = stack.socket("length-prefixed", tls="hw")
        frame = build_message(RNG.integers(100, 200, 4),
                              RNG.integers(1000, 2000, 40))
        s.deliver(s.tls.seal(frame, s.parser.inner))
        socks.append(s)

    def boom(*a, **kw):
        raise RuntimeError("injected crypto fault")

    monkeypatch.setattr("repro.core.stack.keystream_batch", boom)
    with pytest.raises(RuntimeError, match="injected"):
        stack.recv_batch(socks, 1 << 20)
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_grant_into_import_fault_releases_export_pin(monkeypatch):
    """cluster grant_into: a fault in the destination's import_grant must
    release the owner-side export pin, or the owner's pages stay pinned
    forever (no grantee exists to ever complete)."""
    cl = _cluster(2)
    w0, w1 = cl.workers
    src = cl.socket(worker=0)
    src.deliver(build_message(RNG.integers(100, 200, 4),
                              RNG.integers(1000, 2000, 40)))
    src.recv(1 << 20)
    vpi = next(iter(src.connection.anchored))
    assert w0.pages_in_use > 0

    def boom(*a, **kw):
        raise RuntimeError("injected import fault")

    monkeypatch.setattr(w1.registry, "import_grant", boom)
    with pytest.raises(RuntimeError, match="injected"):
        cl.grant_into(w1, vpi)
    assert w0.alloc.granted_out_pages == 0
    assert not cl.lock.held            # the with-statement unwound the lock
    # the anchor is still intact and grantable once the fault clears
    monkeypatch.undo()
    assert cl.grant_into(w1, vpi) is not None
    assert cl.stats["grants"] == 1
