"""kTLS-analogue encrypted datapath: record layer, token cipher, sw/hw
modes through the socket facade, batched crypto rounds, and the fused
kernel's keystream operand."""
import numpy as np
import pytest

from repro.core import (
    CopyCounters,
    CryptoRecordParser,
    LibraStack,
    ProxyRuntime,
    build_chunked_message,
    build_delimited_message,
    build_message,
    open_record,
    open_stream,
    seal_record,
)
from repro.core.crypto import (
    KS_MASK,
    REC_HEADER,
    REC_MAGIC,
    TAG_SLOT,
    RecordAuthError,
    keystream,
    keystream_batch,
    xor_tokens,
)
from repro.core.parser import ChunkedParser, DelimiterParser, LengthPrefixedParser

RNG = np.random.default_rng(77)

BUILDERS = {
    "length-prefixed": build_message,
    "delimiter": build_delimited_message,
    "chunked": lambda m, p: build_chunked_message(
        [p[i : i + 24] for i in range(0, len(p), 24)]),
}


def _stack(**kw):
    kw.setdefault("n_shards", 4)
    kw.setdefault("pages_per_shard", 128)
    kw.setdefault("page_size", 16)
    kw.setdefault("secret", b"tls")
    return LibraStack(**kw)


# ---------------------------------------------------------------------------
# cipher primitives
# ---------------------------------------------------------------------------

def test_keystream_deterministic_and_span_resumable():
    key = b"k" * 16
    full = keystream(key, seq=9, n=100)
    assert full.dtype == np.int64
    assert full.min() >= 0 and full.max() <= KS_MASK   # int32-safe by design
    # any span regenerates independently (partial sends, §A.1 drains)
    parts = [keystream(key, 9, 13, 0), keystream(key, 9, 50, 13),
             keystream(key, 9, 37, 63)]
    assert np.array_equal(np.concatenate(parts), full)
    # different seq / key => different stream
    assert not np.array_equal(keystream(key, 10, 100), full)
    assert not np.array_equal(keystream(b"j" * 16, 9, 100), full)


def test_keystream_batch_matches_per_record_calls():
    keys = [b"a" * 16, b"b" * 16, b"a" * 16]
    seqs, lens, offs = [3, 4, 5], [17, 0, 40], [0, 2, 9]
    batched = keystream_batch(keys, seqs, lens, offsets=offs)
    for got, k, s, n, o in zip(batched, keys, seqs, lens, offs):
        assert np.array_equal(got, keystream(k, s, n, o))


def test_xor_cipher_is_involution_and_int32_safe():
    toks = RNG.integers(0, 2 ** 31 - 1, 64)
    ks = keystream(b"x" * 16, 1, 64)
    enc = xor_tokens(toks, ks)
    assert enc.max() < 2 ** 31          # ciphertext rides the int32 stream
    assert np.array_equal(xor_tokens(enc, ks), toks)


# ---------------------------------------------------------------------------
# record framing
# ---------------------------------------------------------------------------

def test_seal_open_roundtrip_all_inner_protocols():
    key = b"s" * 16
    cases = [
        (LengthPrefixedParser(), build_message(np.arange(5), RNG.integers(0, 9, 30))),
        (DelimiterParser(), build_delimited_message(np.arange(4), RNG.integers(0, 9, 20))),
        (ChunkedParser(), np.concatenate([[19, 6], RNG.integers(0, 9, 6)])),
    ]
    for parser, frame in cases:
        rec = seal_record(key, frame, parser, seq=7)
        assert int(rec[0]) == REC_MAGIC
        # ciphertext differs from plaintext (overwhelmingly likely)
        assert not np.array_equal(rec[REC_HEADER:], frame)
        got, used = open_record(key, rec)
        assert used == len(rec)
        assert np.array_equal(got, frame), parser.name


def test_crypto_record_parser_semantics():
    # header format: [REC_MAGIC, seq, inner_meta_len, payload_len, tag]
    p = CryptoRecordParser()
    assert p.parse(np.array([REC_MAGIC, 1])).need_more          # short header
    assert not p.parse(np.array([99, 0, 0, 0])).ok              # bad magic
    assert not p.parse(np.array([99, 0, 0, 0])).need_more
    assert not p.parse(np.array([REC_MAGIC, 1, -2, 5, 0])).ok   # bad lens
    r = p.parse(np.array([REC_MAGIC, 4, 2, 50, 0, 11, 12]))
    assert r.ok and r.meta_len == REC_HEADER + 2 and r.payload_len == 50
    # header present but inner metadata still arriving
    assert p.parse(np.array([REC_MAGIC, 4, 5, 50, 0, 11])).need_more


# ---------------------------------------------------------------------------
# scalar facade: sw / hw modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["sw", "hw"])
def test_scalar_recv_forward_plaintext_identity(mode):
    stack = _stack()
    src = stack.socket("length-prefixed", tls=mode)
    dst = stack.socket("length-prefixed", tls=mode)
    frame = build_message(RNG.integers(100, 200, 5), RNG.integers(1000, 2000, 40))
    src.deliver(src.tls.seal(frame, src.parser.inner))
    buf, n = src.recv(1 << 20)
    # proxy sees the record header + DECRYPTED inner metadata + VPI
    assert int(buf[0]) == REC_MAGIC
    assert np.array_equal(buf[REC_HEADER : REC_HEADER + 3], frame[:3])
    assert n == REC_HEADER + 8 + 40     # record meta + payload, logical
    src.forward(dst, buf)
    got = open_stream(dst.tls.tx_key, dst.tx_wire())
    assert np.array_equal(got, frame)
    # the anchored payload crossed zero-copy in both modes; only sw paid
    # the separate §B.1 decrypt+encrypt passes
    c = stack.counters
    assert c.anchored == c.zero_copied == 40
    if mode == "sw":
        assert c.crypto_copied == 80    # one decrypt + one encrypt pass
        assert src.tls.stats["sw_decrypt_passes"] == 1
        assert dst.tls.stats["sw_encrypt_passes"] == 1
    else:
        assert c.crypto_copied == 0     # fused: zero extra passes


@pytest.mark.parametrize("mode", ["sw", "hw"])
def test_pool_holds_plaintext(mode):
    """Anchored ciphertext is decrypted exactly once, into the pool — the
    pool content is mode-independent plaintext (what a plaintext socket
    would have anchored)."""
    stack = _stack()
    src = stack.socket("length-prefixed", tls=mode)
    payload = RNG.integers(1000, 2000, 40)
    frame = build_message(np.arange(4), payload)
    src.deliver(src.tls.seal(frame, src.parser.inner))
    src.recv(1 << 20)
    (pages, ln), = src.connection.anchored.values()
    assert np.array_equal(stack.pool.read_payload(pages, ln), payload)


@pytest.mark.parametrize("mode", ["sw", "hw"])
def test_partial_encrypted_send_resumes_under_budget(mode):
    stack = _stack()
    src = stack.socket("length-prefixed", tls=mode)
    dst = stack.socket("length-prefixed", tls=mode)
    frame = build_message(RNG.integers(100, 200, 4), RNG.integers(1000, 2000, 40))
    src.deliver(src.tls.seal(frame, src.parser.inner))
    buf, _ = src.recv(1 << 20)
    sends = [src.forward(dst, buf, budget=13)]
    while dst.pending_send is not None:
        sends.append(dst.send(budget=13))
    assert all(s > 0 for s in sends) and len(sends) > 2
    got = open_stream(dst.tls.tx_key, dst.tx_wire())
    assert np.array_equal(got, frame)


@pytest.mark.parametrize("mode", ["sw", "hw"])
def test_short_record_full_copy_tx_resumes_under_budget(mode):
    """A record whose payload is under the admission threshold takes the
    native full-copy path end to end; the TX keystream must resume across
    budget-truncated chunks (TlsSession.tx_resume)."""
    stack = _stack()
    src = stack.socket("length-prefixed", tls=mode)
    dst = stack.socket("length-prefixed", tls=mode)
    frame = build_message(np.arange(4), np.array([7, 8, 9]))   # payload 3 < 8
    src.deliver(src.tls.seal(frame, src.parser.inner))
    buf, _ = src.recv(1 << 20)
    src.forward(dst, buf, budget=5)
    while dst.pending_send is not None:
        dst.send(budget=5)
    assert np.array_equal(open_stream(dst.tls.tx_key, dst.tx_wire()), frame)
    assert stack.counters.anchored == 0    # never touched the pool


@pytest.mark.parametrize("mode", ["sw", "hw"])
def test_exhaustion_drain_decrypts(mode):
    """§A.1 overflow on an encrypted record: the anchored prefix is
    impossible (pool too small), so the payload drains through the native
    copy path — decrypted span by span across several recv calls."""
    stack = _stack(n_shards=1, pages_per_shard=2)
    src = stack.socket("length-prefixed", tls=mode)
    frame = build_message(RNG.integers(100, 200, 4),
                          RNG.integers(1000, 2000, 80))   # 5 pages > 2-page pool
    src.deliver(src.tls.seal(frame, src.parser.inner))
    parts = [src.recv(1 << 6)[0]]                         # small buffer: drains
    while src.connection.rx_drain_remaining > 0:
        parts.append(src.recv(1 << 6)[0])
    got = np.concatenate(parts)
    assert np.array_equal(got[REC_HEADER:], frame)
    assert stack.counters.full_copied == 80


def test_record_spanning_ring_wrap():
    """A record delivered in dribbles after enough prior traffic that the
    RxRing slides/wraps mid-record: the zero-copy windows, residency gate
    and keystream offsets must all survive the buffer moving under them."""
    stack = _stack()
    src = stack.socket("length-prefixed", tls="hw")
    dst = stack.socket("length-prefixed", tls="hw")
    rng = np.random.default_rng(5)
    frames = []
    for _ in range(6):   # advance the ring head well past the origin
        f = build_message(rng.integers(100, 200, 4), rng.integers(1000, 2000, 24))
        frames.append(f)
        src.deliver(src.tls.seal(f, src.parser.inner))
        buf, _ = src.recv(1 << 20)
        src.forward(dst, buf)
    big = build_message(rng.integers(100, 200, 6), rng.integers(1000, 2000, 64))
    frames.append(big)
    rec = src.tls.seal(big, src.parser.inner)
    for i in range(0, len(rec), 7):
        src.deliver(rec[i : i + 7])
        # L7 gating, as the runtime does: only recv parseable+resident frames
        if not src.needs_more_data():
            buf, n = src.recv(1 << 20)
            if n:
                src.forward(dst, buf)
    got = open_stream(dst.tls.tx_key, dst.tx_wire())
    assert np.array_equal(got, np.concatenate(frames))


# ---------------------------------------------------------------------------
# sw/hw parity through the runtime (chunked + delimiter inner protocols)
# ---------------------------------------------------------------------------

def _run_proxy(tls, *, protos, batched, budget=None, recv_buf=1 << 20,
               n_chans=4, n_msgs=3, payload=72, seed=11):
    stack = _stack()
    rt = ProxyRuntime(stack, tick_every=8, batched=batched)
    rng = np.random.default_rng(seed)
    dsts, wants = [], []
    for i in range(n_chans):
        proto = protos[i % len(protos)]
        src = stack.socket(proto, tls=tls)
        dst = stack.socket(proto, tls=tls)
        rt.channel(src, dst, name=f"{proto}-{i}", budget=budget,
                   recv_buf=recv_buf)
        dsts.append(dst)
        frames = []
        for _ in range(n_msgs):
            msg = BUILDERS[proto](rng.integers(100, 200, 6),
                                  rng.integers(1000, 2000, payload))
            if proto == "chunked":
                # each chunk frame is its own record
                parser = ChunkedParser()
                pos, sub = 0, []
                while pos < len(msg):
                    r = parser.parse(msg[pos:])
                    end = pos + r.meta_len + r.payload_len
                    sub.append(msg[pos:end])
                    pos = end
                frames.extend(sub)
            else:
                frames.append(msg)
        wants.append(np.concatenate(frames))
        if tls:
            src.deliver(src.tls.seal_frames(frames, src.parser.inner))
        else:
            src.deliver(np.concatenate(frames))
    rt.run()
    plains = [open_stream(d.tls.tx_key, d.tx_wire()) if tls else d.tx_wire()
              for d in dsts]
    msgs = rt.messages_forwarded()
    snap = stack.counters.snapshot()
    crypto_copied = stack.counters.crypto_copied
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages
    return plains, wants, msgs, snap, crypto_copied


@pytest.mark.parametrize("batched", [False, True])
def test_sw_hw_parity_chunked_delimiter(batched):
    protos = ("chunked", "delimiter")
    plain, want_p, msgs_p, _, cc_p = _run_proxy(None, protos=protos,
                                                batched=batched)
    sw, want_s, msgs_s, _, cc_s = _run_proxy("sw", protos=protos,
                                             batched=batched)
    hw, want_h, msgs_h, _, cc_h = _run_proxy("hw", protos=protos,
                                             batched=batched)
    assert msgs_p == msgs_s == msgs_h
    for pw, sw_, hw_, want in zip(plain, sw, hw, want_p):
        # every regime forwards byte-identical plaintext
        assert np.array_equal(pw, want)
        assert np.array_equal(sw_, want)
        assert np.array_equal(hw_, want)
    assert cc_p == cc_h == 0 and cc_s > 0


def test_sw_hw_parity_under_budget_and_tiny_recv_buf():
    """Fragmented metadata (tiny recv_buf) and budget-truncated sends, both
    encrypted modes: the reassembly + keystream continuations compose."""
    protos = ("length-prefixed",)
    plain, want, msgs_p, _, _ = _run_proxy(None, protos=protos, batched=False,
                                           budget=20, recv_buf=9)
    for tls in ("sw", "hw"):
        got, _, msgs, _, _ = _run_proxy(tls, protos=protos, batched=False,
                                        budget=20, recv_buf=9)
        assert msgs == msgs_p
        for g, w in zip(got, want):
            assert np.array_equal(g, w), tls


def test_batched_matches_scalar_counters_per_mode():
    """Within each tls mode, the batched scheduler must copy exactly the
    tokens the scalar scheduler copies (sw batches nothing — it falls back
    per message — but the outcome and counters still match)."""
    for tls in (None, "sw", "hw"):
        _, _, msgs_s, snap_s, _ = _run_proxy(
            tls, protos=("length-prefixed", "delimiter"), batched=False)
        _, _, msgs_b, snap_b, _ = _run_proxy(
            tls, protos=("length-prefixed", "delimiter"), batched=True)
        assert msgs_s == msgs_b, tls
        assert snap_s == snap_b, tls


# ---------------------------------------------------------------------------
# batched data plane specifics
# ---------------------------------------------------------------------------

def test_recv_batch_excludes_sw_includes_hw():
    stack = _stack()
    sw = stack.socket("length-prefixed", tls="sw")
    hw = stack.socket("length-prefixed", tls="hw")
    plain = stack.socket("length-prefixed")
    frame = build_message(np.arange(4), RNG.integers(1000, 2000, 32))
    sw.deliver(sw.tls.seal(frame, sw.parser.inner))
    hw.deliver(hw.tls.seal(frame, hw.parser.inner))
    plain.deliver(frame)
    res = stack.recv_batch([sw, hw, plain])
    # sw must take the scalar decrypt-and-copy path (§B.1: software crypto
    # forfeits the fused batch); hw and plaintext ride the batch
    assert set(res) == {hw.fileno(), plain.fileno()}
    buf, n = sw.recv(1 << 20)
    assert n > 0 and stack.counters.crypto_copied == 32


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_recv_batch_kernel_impl_decrypts_like_host(impl):
    def load(stack):
        socks = []
        rng = np.random.default_rng(21)
        for _ in range(3):
            s = stack.socket("length-prefixed", tls="hw")
            f = build_message(rng.integers(100, 200, 5),
                              rng.integers(1000, 2000, 40))
            s.deliver(s.tls.seal(f, s.parser.inner))
            socks.append(s)
        return socks

    sh, sk = _stack(), _stack()
    rh = sh.recv_batch(load(sh), impl="host")
    rk = sk.recv_batch(load(sk), impl=impl)
    assert len(rh) == len(rk) == 3
    assert np.array_equal(sh.pool.data, sk.pool.data)   # plaintext, decrypted
    assert sh.counters.snapshot() == sk.counters.snapshot()
    for (bh, nh), (bk, nk) in zip(rh.values(), rk.values()):
        assert nh == nk
        assert np.array_equal(bh[:-1], bk[:-1])          # VPIs differ only


def test_kernel_keystream_operand_bit_exact_vs_crypto_oracle():
    from repro.kernels import ops, ref
    from repro.kernels.testing import selcopy_crypto_case

    rng = np.random.default_rng(31)
    for b, page, pps, meta_max in [(1, 8, 2, 8), (3, 16, 4, 16)]:
        stream, ml, tl, pool, tables, ks = selcopy_crypto_case(
            rng, b=b, page=page, pps=pps, meta_max=meta_max)
        want = ref.selective_copy_crypto_ref(stream, ml, tl, pool, tables,
                                             ks, meta_max=meta_max)
        for impl in ("ref", "interpret"):
            got = ops.selective_copy(stream, ml, tl, pool, tables,
                                     meta_max=meta_max, impl=impl,
                                     reserved_scratch=True, keystream=ks)
            assert np.array_equal(np.array(got[0]), np.array(want[0])), impl
            assert np.array_equal(np.array(got[1]), np.array(want[1])), impl


def test_mixed_plain_and_hw_sockets_share_one_batch():
    """One fused round over a mix of plaintext and encrypted sockets: the
    keystream sweep only covers the encrypted rows; everyone's plaintext
    lands in the pool."""
    stack = _stack()
    rng = np.random.default_rng(41)
    socks, payloads = [], []
    for i in range(4):
        tls = "hw" if i % 2 else None
        s = stack.socket("length-prefixed", tls=tls)
        p = rng.integers(1000, 2000, 32)
        f = build_message(rng.integers(100, 200, 4), p)
        s.deliver(s.tls.seal(f, s.parser.inner) if tls else f)
        socks.append(s)
        payloads.append(p)
    res = stack.recv_batch(socks)
    assert len(res) == 4
    for s, p in zip(socks, payloads):
        (pages, ln), = s.connection.anchored.values()
        assert np.array_equal(stack.pool.read_payload(pages, ln), p)


# ---------------------------------------------------------------------------
# per-record auth tag (truncated blake2b)
# ---------------------------------------------------------------------------

def _tampered_record(sock, frame, flip_at):
    """Seal a record toward ``sock`` and flip one ciphertext token."""
    rec = sock.tls.seal(frame, sock.parser.inner)
    rec = rec.copy()
    rec[flip_at] ^= 0b101
    return rec


def test_record_tag_is_31_bit_and_survives_proxy_reseal():
    """The tag authenticates the plaintext, so a proxy re-sealing the
    record under its TX key preserves it — the wire-side open (which
    verifies) accepts end-to-end proxied traffic."""
    stack = _stack()
    src = stack.socket("length-prefixed", tls="hw")
    dst = stack.socket("length-prefixed", tls="hw")
    frame = build_message(RNG.integers(100, 200, 5),
                          RNG.integers(1000, 2000, 40))
    rec = src.tls.seal(frame, src.parser.inner)
    assert 0 <= int(rec[TAG_SLOT]) <= KS_MASK
    src.deliver(rec)
    buf, _ = src.recv(1 << 20)
    src.forward(dst, buf)
    # open_stream verifies every record tag; a mismatch would raise
    got = open_stream(dst.tls.tx_key, dst.tx_wire())
    assert np.array_equal(got, frame)


@pytest.mark.parametrize("mode", ["sw", "hw"])
def test_scalar_recv_rejects_tampered_record_and_frees_pages(mode):
    """Tampered payload ciphertext: the RX verify (sw: on the decrypt
    pass; hw: the record-layer check before the fused scatter) rejects
    the record — nothing anchored, nothing delivered, stream advanced
    past it, and the socket keeps working for the next good record."""
    stack = _stack()
    src = stack.socket("length-prefixed", tls=mode)
    frame = build_message(RNG.integers(100, 200, 5),
                          RNG.integers(1000, 2000, 40))
    src.deliver(_tampered_record(src, frame, flip_at=REC_HEADER + 10))
    free0 = stack.alloc.free_pages
    with pytest.raises(RecordAuthError):
        src.recv(1 << 20)
    assert stack.alloc.free_pages == free0           # nothing anchored
    assert src.rx_available() == 0                   # record consumed
    assert src.tls.stats["auth_failures"] == 1
    assert stack.counters.snapshot() == CopyCounters().snapshot()
    # the connection recovers: the next good record flows normally
    good = build_message(RNG.integers(100, 200, 5),
                         RNG.integers(1000, 2000, 40))
    src.deliver(src.tls.seal(good, src.parser.inner))
    buf, n = src.recv(1 << 20)
    assert n == REC_HEADER + 8 + 40


def test_short_record_full_copy_path_rejects_tampering():
    """Records below the admission threshold ride the native full-copy
    path — the sw verify-on-decrypt still rejects tampering there."""
    stack = _stack()
    src = stack.socket("length-prefixed", tls="sw", min_payload=64)
    frame = build_message(RNG.integers(100, 200, 4),
                          RNG.integers(1000, 2000, 16))
    src.deliver(_tampered_record(src, frame, flip_at=REC_HEADER + 8))
    with pytest.raises(RecordAuthError):
        src.recv(1 << 20)
    assert src.rx_available() == 0
    src.deliver(src.tls.seal(frame, src.parser.inner))
    buf, n = src.recv(1 << 20)
    assert np.array_equal(buf[REC_HEADER:], frame)   # decrypted whole record


def test_batched_sweep_rejects_tampered_record_keeps_round_alive():
    """hw-kTLS batched round with one tampered record among good ones:
    the tag check folded into the keystream sweep drops ONLY the bad
    slot — its pages return to the freelist, its bytes are consumed —
    while the rest of the round anchors and delivers normally."""
    stack = _stack()
    socks, frames = [], []
    for i in range(4):
        s = stack.socket("length-prefixed", tls="hw")
        f = build_message(RNG.integers(100, 200, 5),
                          RNG.integers(1000, 2000, 40))
        socks.append(s)
        frames.append(f)
        if i == 2:
            s.deliver(_tampered_record(s, f, flip_at=REC_HEADER + 20))
        else:
            s.deliver(s.tls.seal(f, s.parser.inner))
    free0 = stack.alloc.free_pages
    results = stack.recv_batch(socks)
    good_fds = {s.fileno() for i, s in enumerate(socks) if i != 2}
    assert set(results) == good_fds
    assert socks[2].tls.stats["auth_failures"] == 1
    assert socks[2].rx_available() == 0              # bad record consumed
    # only the good records' pages stay anchored
    assert stack.alloc.free_pages == free0 - 3 * 3   # 40 tokens = 3 pages
    # good flows decrypted correctly (inner metadata surfaced plaintext)
    for i, s in enumerate(socks):
        if i == 2:
            continue
        buf, n = results[s.fileno()]
        assert np.array_equal(buf[REC_HEADER:-1], frames[i][:8])
        assert n == REC_HEADER + 8 + 40


def test_tampered_metadata_ciphertext_also_rejected():
    stack = _stack()
    src = stack.socket("length-prefixed", tls="hw")
    frame = build_message(RNG.integers(100, 200, 5),
                          RNG.integers(1000, 2000, 40))
    src.deliver(_tampered_record(src, frame, flip_at=REC_HEADER + 1))
    with pytest.raises(RecordAuthError):
        src.recv(1 << 20)
    assert src.tls.stats["auth_failures"] == 1


def test_partial_serve_of_resident_tampered_record_rejected():
    """A tiny user buffer serving only a prefix of a full-copy record must
    not leak tampered plaintext: the whole resident record is verified
    BEFORE any byte reaches the caller."""
    stack = _stack()
    src = stack.socket("length-prefixed", tls="sw", min_payload=64)
    frame = build_message(RNG.integers(100, 200, 4),
                          RNG.integers(1000, 2000, 16))
    src.deliver(_tampered_record(src, frame, flip_at=REC_HEADER + 9))
    with pytest.raises(RecordAuthError):
        src.recv(7)                       # buffer far smaller than record
    assert src.rx_available() == 0        # whole record consumed
    assert src.tls.stats["auth_failures"] == 1
    # and a good record still serves fine through a tiny buffer
    src.deliver(src.tls.seal(frame, src.parser.inner))
    buf, n = src.recv(7)
    assert n == 7 and np.array_equal(buf[REC_HEADER:7], frame[:2])
