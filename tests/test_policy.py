"""In-data-plane L7 policy engine: the offloaded PolicyTable must route
byte-, counter-, and verdict-identically to the same rules evaluated by
per-message Python callbacks — across scalar and batched schedules,
plaintext and hw-kTLS records, single stacks and 4-worker clusters — while
DROP frees anchored pages and RATE_LIMIT debits deterministic token
buckets.  Property tests pin the compile round-trip and the kernel/naive-
interpreter agreement."""
import numpy as np
import pytest

from repro.core import (
    ClusterRuntime,
    LibraCluster,
    LibraStack,
    PolicyTable,
    ProxyRuntime,
    PythonPolicyRouter,
    between,
    build_message,
    drop,
    eq,
    forward,
    prefix,
    punt,
    rate_limit,
    rewrite,
    rule,
)
from repro.core.crypto import REC_HEADER
from repro.core.policy import (
    ACT_DROP,
    ACT_FORWARD,
    ACT_PUNT,
    ACT_RATE_LIMIT,
    ACT_REWRITE,
    Action,
    PUNT_RATE_LIMITED,
    PUNT_REWRITE_CRYPTO,
)

from _hypothesis_compat import given, settings, st

RNG = np.random.default_rng(31)

STACK_KW = dict(n_shards=4, pages_per_shard=128, page_size=16, secret=b"pl")

#: length-prefixed header is [MAGIC, len_meta, len_payload, meta...] — app
#: metadata starts at token 3
TAG = 3


def _stack():
    return LibraStack(**STACK_KW)


def _trace(tags, seed=5, payload_max=40):
    rng = np.random.default_rng(seed)
    return [build_message(np.array([t, 50 + i, 60 + i]),
                          rng.integers(1000, 2000,
                                       int(rng.integers(8, payload_max))))
            for i, t in enumerate(tags)]


def _run_offloaded(table, msgs, n_backends=2, batched=False,
                   batch_impl="host"):
    stack = _stack()
    src = stack.socket("length-prefixed")
    dsts = [stack.socket("length-prefixed") for _ in range(n_backends)]
    rt = ProxyRuntime(stack, policy=table, batched=batched,
                      batch_impl=batch_impl)
    ch = rt.channel(src, dsts)
    for m in msgs:
        src.deliver(m)
    rt.run()
    return stack, dsts, ch, table


def _run_python(table, msgs, n_backends=2, batched=False):
    stack = _stack()
    src = stack.socket("length-prefixed")
    dsts = [stack.socket("length-prefixed") for _ in range(n_backends)]
    rt = ProxyRuntime(stack, batched=batched)
    pr = PythonPolicyRouter(table, dsts, parser=src.parser, stack=stack)
    ch = rt.channel(src, dsts, rewrite=pr.rewrite, router=pr.router)
    for m in msgs:
        src.deliver(m)
    rt.run()
    return stack, dsts, ch, table


def _stats(table):
    s = table.summary()
    # "rounds" counts match passes (per round when fused, per message in
    # Python) — the one legitimately schedule-dependent number
    s.pop("rounds")
    s.pop("buckets")
    return s


def _assert_identical(a, b, *, policy_counters=True):
    """Byte + Fig. 9 + table-stats identity between two runs."""
    sa, da, ca, ta = a
    sb, db, cb, tb = b
    for x, y in zip(da, db):
        assert np.array_equal(x.tx_wire(), y.tx_wire())
    assert sa.counters.snapshot() == sb.counters.snapshot()
    assert _stats(ta) == _stats(tb)
    assert ca.stats.drops == cb.stats.drops
    assert sa.pages_in_use == sb.pages_in_use
    if policy_counters:
        for f in ("policy_hits", "policy_punts", "policy_drops",
                  "policy_rate_debits"):
            assert getattr(sa.counters, f) == getattr(sb.counters, f), f


# ---------------------------------------------------------------------------
# scenario: sticky-session affinity
# ---------------------------------------------------------------------------

def _sticky_table():
    # four sessions pinned to backends: the table IS the affinity map
    return PolicyTable([
        rule(forward(0), eq(TAG, 200)), rule(forward(1), eq(TAG, 201)),
        rule(forward(0), eq(TAG, 202)), rule(forward(1), eq(TAG, 203)),
    ])


@pytest.mark.parametrize("batched", [False, True])
def test_sticky_session_affinity_identity(batched):
    tags = RNG.choice([200, 201, 202, 203], 32)
    msgs = _trace(tags, seed=7)
    off = _run_offloaded(_sticky_table(), msgs, batched=batched)
    py = _run_python(_sticky_table(), msgs, batched=batched)
    # affinity: every session's bytes land on exactly one backend
    for sess, k in [(200, 0), (201, 1), (202, 0), (203, 1)]:
        wire = off[1][k].tx_wire()
        n_sess = int((tags == sess).sum())
        assert (wire == sess).sum() == n_sess    # each header tag appears
        other = off[1][1 - k].tx_wire()
        assert (other == sess).sum() == 0
    _assert_identical(off[:4], py[:4], policy_counters=False)
    assert off[0].counters.policy_hits == len(msgs)
    assert off[0].counters.policy_punts == 0


# ---------------------------------------------------------------------------
# scenario: 70/30 weighted backends
# ---------------------------------------------------------------------------

def _weighted_table():
    # weight on a per-message hash token (slot TAG+1): 0-69 → A, 70-99 → B
    return PolicyTable([
        rule(forward(0), between(TAG, 0, 69)),
        rule(forward(1), between(TAG, 70, 99)),
    ])


@pytest.mark.parametrize("batched", [False, True])
def test_weighted_70_30_split_identity(batched):
    rng = np.random.default_rng(17)
    tags = rng.integers(0, 100, 64)
    msgs = _trace(tags, seed=8)
    off = _run_offloaded(_weighted_table(), msgs, batched=batched)
    py = _run_python(_weighted_table(), msgs, batched=batched)
    _assert_identical(off[:4], py[:4], policy_counters=False)
    hits = off[3].stats["rule_hits"]
    assert hits[0] == int((tags < 70).sum())
    assert hits[1] == int((tags >= 70).sum())
    # the draw itself is ~70/30; the table must reproduce it exactly
    assert hits[0] + hits[1] == len(msgs)
    assert off[0].counters.policy_hits == len(msgs)


# ---------------------------------------------------------------------------
# scenario: per-tenant token bucket
# ---------------------------------------------------------------------------

def _rate_table():
    # 1 token/tick refill, burst 3, keyed by the tenant token at TAG
    return PolicyTable([
        rule(rate_limit(1.0, burst=3.0, per=TAG), between(TAG, 0, 10 ** 6)),
    ])


@pytest.mark.parametrize("batched", [False, True])
def test_per_tenant_token_bucket_identity(batched):
    def build(n_tenants=2, per_tenant=6):
        stack = _stack()
        table = _rate_table()
        chans = []
        for t in range(n_tenants):
            src = stack.socket("length-prefixed")
            d0 = stack.socket("length-prefixed")
            for m in _trace([100 + t] * per_tenant, seed=20 + t):
                src.deliver(m)
            chans.append((src, d0))
        return stack, table, chans

    def run(offloaded):
        stack, table, chans = build()
        # tick_every large: now stays 0 for the whole run, so each tenant
        # gets exactly its burst (3) through and punts the rest
        rt = ProxyRuntime(stack, tick_every=10 ** 6, batched=batched,
                          policy=table if offloaded else None)
        for src, d0 in chans:
            if offloaded:
                rt.channel(src, [d0])
            else:
                pr = PythonPolicyRouter(table, [d0], parser=src.parser,
                                        stack=stack)
                rt.channel(src, [d0], rewrite=pr.rewrite, router=pr.router)
        rt.run()
        return stack, [d for _, d in chans], rt.channels[0], table

    off, py = run(True), run(False)
    _assert_identical(off, py, policy_counters=False)
    st_ = _stats(off[3])
    assert st_["rate_debits"] == 6            # burst of 3 × 2 tenants
    assert st_["punts_by_reason"] == {PUNT_RATE_LIMITED: 6}
    assert off[0].counters.policy_rate_debits == 6
    # punted messages still flowed (dsts[0] is the punt default): per
    # tenant all 6 messages are on the wire, 3 via verdict + 3 via punt
    for _, d in [(None, x) for x in off[1]]:
        assert len(d.tx_wire()) > 0


def test_token_bucket_refills_across_ticks():
    table = _rate_table()
    stack = _stack()
    src = stack.socket("length-prefixed")
    d0 = stack.socket("length-prefixed")
    rt = ProxyRuntime(stack, tick_every=1, policy=table)  # tick every round
    rt.channel(src, [d0])
    for m in _trace([100] * 8, seed=3):
        src.deliver(m)
    rt.run()
    # one tick per round → the bucket refills a token between messages and
    # never runs dry
    assert _stats(table)["punts"] == 0
    assert _stats(table)["rate_debits"] == 8


# ---------------------------------------------------------------------------
# scenario: DROP frees the anchored pages
# ---------------------------------------------------------------------------

def _drop_table():
    return PolicyTable([rule(drop(), eq(TAG, 103)),
                        rule(forward(0), between(TAG, 0, 10 ** 6))])


@pytest.mark.parametrize("batched", [False, True])
def test_drop_frees_pages_and_keeps_fig9_identity(batched):
    tags = [103, 101, 103, 102, 103, 105]
    msgs = _trace(tags, seed=9)
    off = _run_offloaded(_drop_table(), msgs, batched=batched)
    py = _run_python(_drop_table(), msgs, batched=batched)
    stack, dsts, ch, table = off
    assert stack.pages_in_use == 0            # every dropped anchor freed
    assert stack.counters.policy_drops == 3
    assert ch.stats.drops == 3
    assert ch.stats.messages == 3             # only the survivors transmit
    # Fig. 9 identity: the DROP applies after full registration, so the
    # copy-volume counters equal the Python-callback run's exactly
    _assert_identical(off[:4], py[:4], policy_counters=False)
    # and the registry holds no leaked handles
    assert len(stack.registry) == 0


# ---------------------------------------------------------------------------
# REWRITE: header patch on plaintext, PUNT on sealed records
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batched", [False, True])
def test_rewrite_patches_header_on_the_wire(batched):
    table = PolicyTable([rule(rewrite(TAG + 1, 9999, backend=0),
                              eq(TAG, 104))])
    msgs = _trace([104, 104], seed=11)
    stack, dsts, ch, _ = _run_offloaded(table, msgs, batched=batched)
    wire = dsts[0].tx_wire()
    assert (wire == 9999).sum() == 2          # both headers patched
    py = _run_python(table, msgs, batched=batched)
    assert np.array_equal(wire, py[1][0].tx_wire())


def test_rewrite_on_crypto_record_punts():
    off = REC_HEADER + TAG
    table = PolicyTable([rule(rewrite(off + 1, 9999, backend=0),
                              eq(off, 104))])
    stack = _stack()
    src = stack.socket("length-prefixed", tls="hw")
    d0 = stack.socket("length-prefixed", tls="hw")
    rt = ProxyRuntime(stack, policy=table)
    rt.channel(src, [d0])
    for f in _trace([104, 104], seed=12):
        src.deliver(src.tls.seal(f, src.parser.inner))
    rt.run()
    s = _stats(table)
    assert s["punts_by_reason"] == {PUNT_REWRITE_CRYPTO: 2}
    # the messages still flowed unpatched through the punt default
    plain = d0.tls.open_wire(d0.tx_wire())
    assert (plain == 9999).sum() == 0
    assert (plain == 104).sum() == 2


# ---------------------------------------------------------------------------
# hw-kTLS: fused ciphertext+keystream match == Python-on-plaintext
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["host", "ref", "interpret"])
def test_hw_ktls_policy_identity(impl):
    off = REC_HEADER + TAG
    def table():
        return PolicyTable([
            rule(forward(0), eq(off, 101)), rule(forward(1), eq(off, 102)),
            rule(drop(), eq(off, 103)),
        ])

    rng = np.random.default_rng(14)
    frames = _trace(rng.choice([101, 102, 103, 105], 16), seed=15)

    def run(offloaded):
        stack = _stack()
        src = stack.socket("length-prefixed", tls="hw")
        dsts = [stack.socket("length-prefixed", tls="hw") for _ in range(2)]
        t = table()
        if offloaded:
            rt = ProxyRuntime(stack, policy=t, batched=True, batch_impl=impl)
            ch = rt.channel(src, dsts)
        else:
            rt = ProxyRuntime(stack)
            pr = PythonPolicyRouter(t, dsts, parser=src.parser, crypto=True,
                                    stack=stack)
            ch = rt.channel(src, dsts, rewrite=pr.rewrite, router=pr.router)
        for f in frames:
            src.deliver(src.tls.seal(f, src.parser.inner))
        rt.run()
        # TLS keys derive from per-process connection ids, so ciphertext is
        # not comparable across runs — decrypted wires are
        return ([d.tls.open_wire(d.tx_wire()).tolist() for d in dsts],
                stack.counters.snapshot(), _stats(t), ch.stats.drops)

    o, p = run(True), run(False)
    assert o == p


# ---------------------------------------------------------------------------
# 4-worker cluster: per-worker tables, cross-worker FORWARD, aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batched", [False, True])
def test_cluster_policy_identity_and_aggregation(batched):
    def table():
        return PolicyTable([
            rule(forward(0), eq(TAG, 101)), rule(forward(1), eq(TAG, 102)),
            rule(drop(), eq(TAG, 103)),
        ])

    rng = np.random.default_rng(21)
    traces = [_trace(rng.choice([101, 102, 103, 105], 8), seed=30 + c)
              for c in range(4)]

    def run(offloaded):
        cl = LibraCluster(4, **STACK_KW)
        crt = ClusterRuntime(cl, policy=table() if offloaded else None,
                             batched=batched)
        outs = []
        for c, msgs in enumerate(traces):
            src = cl.socket(worker=c)
            b0 = cl.socket(worker=c)
            b1 = cl.socket(worker=(c + 1) % 4)  # FORWARD(1) crosses workers
            if offloaded:
                crt.channel(src, [b0, b1])
            else:
                stack = crt.runtimes[src.worker_id].stack
                pr = PythonPolicyRouter(table(), [b0, b1], parser=src.parser,
                                        stack=stack)
                crt.channel(src, [b0, b1], rewrite=pr.rewrite,
                            router=pr.router)
            for m in msgs:
                src.deliver(m)
            outs.append((b0, b1))
        crt.run()
        agg = cl.counters_aggregate()
        wires = [(a.tx_wire().tolist(), b.tx_wire().tolist())
                 for a, b in outs]
        return wires, agg.snapshot(), agg.cross_worker_grants, crt, cl

    ow, osnap, ogr, ocrt, ocl = run(True)
    pw, psnap, pgr, _, _ = run(False)
    assert ow == pw
    assert osnap == psnap
    assert ogr == pgr and ogr > 0             # the grant path was exercised
    # telemetry aggregation mirrors counters_aggregate: worker sums == total
    summ = ocrt.policy_summary()
    per = [s for s in summ["per_worker"] if s is not None]
    assert len(per) == 4
    assert summ["aggregate"]["forwards"] == sum(s["forwards"] for s in per)
    assert summ["aggregate"]["drops"] == sum(s["drops"] for s in per)
    # policy event counters aggregate like cross_worker_grants does
    agg = ocl.counters_aggregate()
    assert agg.policy_drops == sum(
        w.counters.policy_drops for w in ocl.workers)
    assert agg.policy_drops == summ["aggregate"]["drops"]


def test_cluster_policy_factory_builds_per_worker_tables():
    built = []

    def factory(worker_id):
        t = PolicyTable([rule(forward(0), eq(TAG, 100 + worker_id))])
        built.append((worker_id, t))
        return t

    cl = LibraCluster(2, **STACK_KW)
    crt = ClusterRuntime(cl, policy=factory)
    assert [w for w, _ in built] == [0, 1]
    assert crt.runtimes[0].policy is built[0][1]
    assert crt.runtimes[1].policy is built[1][1]
    # plain tables are cloned per worker (independent bucket state)
    t = PolicyTable([rule(forward(0), eq(TAG, 1))])
    crt2 = ClusterRuntime(LibraCluster(2, **STACK_KW), policy=t)
    assert crt2.policies[0] is not t and crt2.policies[1] is not t
    assert crt2.policies[0].rules == t.rules


# ---------------------------------------------------------------------------
# counters: snapshot exclusion + mixed-table fusion guard
# ---------------------------------------------------------------------------

def test_policy_counters_stay_out_of_fig9_snapshot():
    stack = _stack()
    stack.counters.policy_hits = 99
    stack.counters.policy_punts = 98
    stack.counters.policy_drops = 97
    stack.counters.policy_rate_debits = 96
    clean = LibraStack(**STACK_KW)
    assert stack.counters.snapshot() == clean.counters.snapshot()


def test_mixed_tables_in_one_tile_still_identical():
    """Channels with different tables share a batched round: the fused
    pass is skipped (it would double-debit buckets) but per-channel
    resolution must still match the pure-Python run."""
    ta = PolicyTable([rule(forward(0), eq(TAG, 101)),
                      rule(drop(), eq(TAG, 103))])
    tb = PolicyTable([rule(forward(0), eq(TAG, 103))])  # opposite verdicts

    def run(offloaded):
        stack = _stack()
        outs = []
        rt = ProxyRuntime(stack, batched=True)
        for t, seed in [(ta if offloaded else ta.clone(), 40),
                        (tb if offloaded else tb.clone(), 41)]:
            src = stack.socket("length-prefixed")
            d0 = stack.socket("length-prefixed")
            if offloaded:
                rt.channel(src, [d0], policy=t)
            else:
                pr = PythonPolicyRouter(t, [d0], parser=src.parser,
                                        stack=stack)
                rt.channel(src, [d0], rewrite=pr.rewrite, router=pr.router)
            for m in _trace([101, 103, 101, 103], seed=seed):
                src.deliver(m)
            outs.append(d0)
        rt.run()
        return [d.tx_wire().tolist() for d in outs], \
            stack.counters.snapshot()

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# property: compile round-trip
# ---------------------------------------------------------------------------

def _random_rule(rng):
    n_conds = int(rng.integers(1, 4))
    conds = []
    for _ in range(n_conds):
        off = int(rng.integers(0, 12))
        lo = int(rng.integers(0, 180))
        conds.append(between(off, lo, lo + int(rng.integers(0, 60))))
    kind = int(rng.integers(0, 5))
    if kind == ACT_FORWARD:
        act = forward(int(rng.integers(0, 4)))
    elif kind == ACT_REWRITE:
        act = rewrite(int(rng.integers(0, 12)), int(rng.integers(0, 10 ** 6)),
                      backend=int(rng.integers(0, 4)))
    elif kind == ACT_RATE_LIMIT:
        act = rate_limit(int(rng.integers(1, 50)) / 10.0,
                         burst=int(rng.integers(10, 80)) / 10.0,
                         backend=int(rng.integers(0, 4)),
                         per=int(rng.integers(-1, 12)))
    elif kind == ACT_DROP:
        act = drop()
    else:
        act = punt()
    return rule(act, *conds)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 12), st.integers(0, 10 ** 6))
def test_compile_roundtrip_preserves_rules(n_rules, seed):
    rng = np.random.default_rng(seed)
    t = PolicyTable([_random_rule(rng) for _ in range(n_rules)])
    t2 = PolicyTable.decode(*t.dense())
    # lossless: the dense arrays decode back to the same ordered rules
    assert t2.rules == t.rules
    # and first-match order is preserved through the round-trip
    metas = rng.integers(0, 240, (16, 12))
    for m in metas:
        assert t.interpret(m, 12) == t2.interpret(m, 12)


# ---------------------------------------------------------------------------
# property: kernel == numpy == naive interpreter on random traffic
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 10), st.integers(0, 10 ** 6))
def test_match_impls_agree_on_random_traffic(n_rules, seed):
    rng = np.random.default_rng(seed)
    t = PolicyTable([_random_rule(rng) for _ in range(n_rules)])
    b, mm = 8, 12
    metas = rng.integers(0, 240, (b, mm))
    lens = rng.integers(1, mm + 1, b).astype(np.int32)
    naive = np.array([t.interpret(metas[i], int(lens[i]))
                      for i in range(b)])
    host = t.match_rows(metas, lens)
    assert np.array_equal(host, naive)
    for impl in ("ref", "interpret"):
        got = t.match_batch(metas, lens, impl=impl)
        assert np.array_equal(np.asarray(got), naive), impl
    # hw-kTLS operand: matching ciphertext ⊕ keystream == plaintext match
    ks = rng.integers(0, 1 << 31, (b, mm))
    pos = np.arange(mm)[None, :]
    ks = np.where(pos < lens[:, None], ks, 0)
    cipher = np.bitwise_xor(metas, ks)
    assert np.array_equal(t.match_rows(cipher, lens, keystreams=ks), naive)
    got = t.match_batch(cipher, lens, keystreams=ks, impl="ref")
    assert np.array_equal(np.asarray(got), naive)


def test_first_match_wins_over_later_rules():
    t = PolicyTable([rule(forward(0), eq(0, 5)),
                     rule(drop(), eq(0, 5)),
                     rule(forward(1), between(0, 0, 100))])
    assert t.interpret(np.array([5, 0]), 2) == 0
    assert t.interpret(np.array([7, 0]), 2) == 2
    assert t.interpret(np.array([101, 0]), 2) == t.n_rules


def test_prefix_helper_expands_to_consecutive_eq_conds():
    t = PolicyTable([rule(forward(0), prefix(17, 3))])
    assert t.interpret(np.array([17, 3, 9]), 3) == 0
    assert t.interpret(np.array([17, 4, 9]), 3) == t.n_rules


def test_dense_arrays_are_int32():
    t = PolicyTable([_random_rule(np.random.default_rng(2))
                     for _ in range(5)])
    for a in t.dense():
        assert a.dtype == np.int32
