"""ProxyRuntime: one stack multiplexing many connections with mixed parser
policies — readiness scheduling, send budgets, interleaved deliveries,
counter accounting, and teardown."""
import numpy as np
import pytest

from repro.core import (
    Events,
    LibraStack,
    ProxyRuntime,
    build_chunked_message,
    build_delimited_message,
    build_message,
)

RNG = np.random.default_rng(11)


def _stack(**kw):
    kw.setdefault("n_shards", 4)
    kw.setdefault("pages_per_shard", 128)
    kw.setdefault("page_size", 16)
    kw.setdefault("secret", b"rt")
    return LibraStack(**kw)


def test_multiplex_three_connections_mixed_parsers():
    """One stack, ≥3 concurrent flows with different parsers, interleaved
    deliveries; every payload arrives intact and the global CopyCounters
    equal the sum of per-path expectations."""
    stack = _stack()
    rt = ProxyRuntime(stack, tick_every=4)
    n_msgs, meta_n, payload_n, chunk = 6, 4, 48, 24

    chans = {}
    for proto in ("length-prefixed", "delimiter", "chunked"):
        src, dst = stack.socket_pair(proto)
        chans[proto] = (src, dst, rt.channel(src, dst, name=proto))

    payloads = {p: [] for p in chans}
    # interleave deliveries round-robin across connections
    for i in range(n_msgs):
        for proto, (src, _, _) in chans.items():
            meta = RNG.integers(100, 200, meta_n)
            payload = RNG.integers(1000, 2000, payload_n)
            payloads[proto].append(payload)
            if proto == "length-prefixed":
                src.deliver(build_message(meta, payload))
            elif proto == "delimiter":
                src.deliver(build_delimited_message(meta, payload))
            else:
                src.deliver(build_chunked_message(
                    [payload[:chunk], payload[chunk:]]))

    rt.run()

    # every payload crossed intact, in order
    for proto, (_, dst, _) in chans.items():
        wire = dst.tx_wire()
        flat = np.concatenate(payloads[proto])
        if proto == "length-prefixed":
            got = wire.reshape(n_msgs, 3 + meta_n + payload_n)[:, -payload_n:]
        elif proto == "delimiter":
            got = wire.reshape(n_msgs, meta_n + 5 + payload_n)[:, -payload_n:]
        else:
            per = wire.reshape(n_msgs, 2 * (2 + chunk) + 2)[:, :-2]
            got = per.reshape(n_msgs, 2, 2 + chunk)[:, :, 2:]
        assert np.array_equal(got.reshape(-1), flat)

    # counter accounting: each selective message copies its metadata twice
    # (rx + tx), anchors its payload once, zero-copies it once; the chunked
    # terminator (2 tokens) is below the admission threshold -> full copy
    # on both sides.
    c = stack.counters
    lp_meta = 3 + meta_n
    dl_meta = meta_n + 4 + 1
    ck_meta = 2 * 2                       # two chunk headers per message
    assert c.meta_copied == 2 * n_msgs * (lp_meta + dl_meta + ck_meta)
    assert c.full_copied == 2 * n_msgs * 2
    assert c.anchored == 3 * n_msgs * payload_n
    assert c.zero_copied == 3 * n_msgs * payload_n
    assert c.vpi_injected == n_msgs * (1 + 1 + 2)
    assert len(stack.registry) == 0
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_budget_partial_messages_stay_ordered():
    """A budget-truncated message must finish before the next one starts on
    the same flow (TCP ordering per connection)."""
    stack = _stack()
    rt = ProxyRuntime(stack)
    src, dst = stack.socket_pair("length-prefixed")
    ch = rt.channel(src, dst, budget=10)
    p1 = RNG.integers(1000, 2000, 40)
    p2 = RNG.integers(2000, 3000, 40)
    src.deliver(build_message(np.arange(3), p1))
    src.deliver(build_message(np.arange(3), p2))
    rt.run()
    wire = dst.tx_wire()
    assert ch.stats.messages == 2 and ch.stats.partial_sends > 0
    assert np.array_equal(wire[6 : 46], p1)
    assert np.array_equal(wire[-40:], p2)


def test_router_selects_backend_by_header():
    stack = _stack()
    rt = ProxyRuntime(stack)
    src = stack.socket("length-prefixed")
    backends = [stack.socket("length-prefixed") for _ in range(3)]
    rt.channel(src, backends, router=lambda buf, n: backends[int(buf[3]) % 3])
    for tag in (0, 1, 2, 1):
        src.deliver(build_message(np.array([tag]), RNG.integers(0, 9, 32)))
    rt.run()
    lens = [len(b.tx_wire()) for b in backends]
    assert lens == [36, 72, 36]   # 3 hdr + 1 meta + 32 payload per message


def test_priority_scheduler_orders_ready_set():
    stack = _stack()
    rt = ProxyRuntime(stack, scheduler="priority")
    order = []

    def mk_rewrite(name):
        def rewrite(buf, n):
            order.append(name)
            return buf
        return rewrite

    for name, prio in (("lo", 0), ("hi", 5), ("mid", 2)):
        src, dst = stack.socket_pair("length-prefixed")
        rt.channel(src, dst, rewrite=mk_rewrite(name), priority=prio,
                   name=name)
        src.deliver(build_message(np.arange(2), RNG.integers(0, 9, 32)))
    rt.step()
    assert order == ["hi", "mid", "lo"]


def test_round_robin_rotates_service_order():
    stack = _stack()
    rt = ProxyRuntime(stack)
    order = []

    def mk_rewrite(name):
        def rewrite(buf, n):
            order.append(name)
            return buf
        return rewrite

    for name in ("a", "b", "c"):
        src, dst = stack.socket_pair("length-prefixed")
        rt.channel(src, dst, rewrite=mk_rewrite(name), name=name)
        for _ in range(2):
            src.deliver(build_message(np.arange(2), RNG.integers(0, 9, 32)))
    rt.step()
    rt.step()
    assert order[:3] == ["a", "b", "c"]
    assert order[3:] == ["b", "c", "a"]   # rotated start


def test_small_recv_buf_reassembles_before_routing():
    """Regression: a recv_buf smaller than metadata+VPI fragments one
    message across several recv calls; the channel must reassemble it and
    route/forward it exactly once (never hand an empty FAST_PATH fragment
    to the router)."""
    stack = _stack()
    rt = ProxyRuntime(stack)
    src = stack.socket("length-prefixed")
    backends = [stack.socket("length-prefixed") for _ in range(2)]
    routed = []

    def router(buf, n):
        routed.append((len(buf), n))
        return backends[int(buf[3]) % 2]

    ch = rt.channel(src, backends, router=router, recv_buf=4)
    payload = RNG.integers(1000, 2000, 64)
    src.deliver(build_message(np.array([101, 7, 7, 7]), payload))
    rt.run()
    # routed once, with the fully reassembled [meta..., VPI] buffer
    assert routed == [(3 + 4 + 1, 3 + 4 + 64)]
    assert ch.stats.messages == 1
    assert np.array_equal(backends[1].tx_wire()[-64:], payload)
    assert len(backends[0].tx_wire()) == 0
    assert len(stack.registry) == 0


def test_runtime_tick_drives_deferred_teardown():
    stack = _stack(grace_ticks=2)
    rt = ProxyRuntime(stack, tick_every=1)
    src, dst = stack.socket_pair("length-prefixed")
    rt.channel(src, dst)
    src.deliver(build_message(np.arange(3), RNG.integers(0, 9, 64)))
    src.recv(1 << 20)      # anchor, then close with the message in flight
    src.close()
    assert stack.pages_in_use == 4
    # idle steps still advance the clock via tick_every
    for _ in range(4):
        rt.step()
    assert stack.pages_in_use == 0
    assert len(stack.registry) == 0


def test_shared_backend_holds_message_until_send_buffer_frees():
    """Two channels sharing one backend socket: while channel A's message
    is budget-truncated, channel B's message is held (EAGAIN) and retried —
    both arrive whole, never interleaved."""
    stack = _stack()
    rt = ProxyRuntime(stack)
    shared = stack.socket("length-prefixed")
    pa = RNG.integers(1000, 2000, 40)
    pb = RNG.integers(3000, 4000, 40)
    for payload, budget in ((pa, 8), (pb, None)):
        src = stack.socket("length-prefixed")
        rt.channel(src, shared, budget=budget)
        src.deliver(build_message(np.arange(3), payload))
    rt.run()
    wire = shared.tx_wire()
    assert len(wire) == 2 * 46
    # channel A's truncated message finishes before B's is admitted
    assert np.array_equal(wire[6:46], pa)
    assert np.array_equal(wire[-40:], pb)
    assert sum(c.stats.messages for c in rt.channels) == 2


def test_trickled_delivery_waits_for_frame_then_anchors():
    """A message arriving in small network chunks must be forwarded as ONE
    selectively-copied message once framable — never as raw fragments."""
    stack = _stack()
    rt = ProxyRuntime(stack)
    src, dst = stack.socket_pair("length-prefixed")
    ch = rt.channel(src, dst)
    payload = RNG.integers(1000, 2000, 32)
    msg = build_message(np.arange(4), payload)
    for lo in range(0, len(msg), 5):      # 5-token trickles
        src.deliver(msg[lo : lo + 5])
        rt.step()
    rt.run()
    assert ch.stats.messages == 1
    assert stack.counters.zero_copied == 32      # anchored, not full-copied
    assert np.array_equal(dst.tx_wire()[-32:], payload)


def test_client_close_mid_truncated_send_still_drains():
    """Regression: a client closing while its message is budget-truncated
    must not strand the backend — the frame finishes transmitting (§A.4)
    and other channels sharing the backend proceed."""
    stack = _stack(grace_ticks=3)
    rt = ProxyRuntime(stack, tick_every=1)
    shared = stack.socket("length-prefixed")
    pa = RNG.integers(1000, 2000, 40)
    pb = RNG.integers(3000, 4000, 40)
    a = stack.socket("length-prefixed")
    ch_a = rt.channel(a, shared, budget=16)
    a.deliver(build_message(np.arange(3), pa))
    rt.step()                     # truncated: backend pending
    assert shared.pending_send is not None
    a.close()                     # client vanishes mid-send
    b = stack.socket("length-prefixed")
    rt.channel(b, shared)
    b.deliver(build_message(np.arange(3), pb))
    rt.run()
    wire = shared.tx_wire()
    assert shared.pending_send is None
    assert np.array_equal(wire[6:46], pa)    # A's frame finished first
    assert np.array_equal(wire[-40:], pb)    # then B flowed
    stack.drain()
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_shutdown_reclaims_everything():
    stack = _stack()
    rt = ProxyRuntime(stack)
    for proto in ("length-prefixed", "delimiter"):
        src, dst = stack.socket_pair(proto)
        rt.channel(src, dst)
        src.deliver(build_message(np.arange(3), RNG.integers(0, 9, 48))
                    if proto == "length-prefixed" else
                    build_delimited_message(np.arange(3),
                                            RNG.integers(0, 9, 48)))
        src.recv(1 << 20)  # leave a message half-proxied
    rt.shutdown()
    assert all(s.closed for ch in rt.channels for s in [ch.src] + ch.dsts)
    assert stack.alloc.free_pages == stack.alloc.total_pages
    assert len(stack.registry) == 0


# ---------------------------------------------------------------------------
# deficit round robin (weighted-fair scheduling)
# ---------------------------------------------------------------------------

def _drr_load(stack, rt, *, big=1000, small=100, n_big=30, n_small=450):
    """Two backlogged flows with ~10:1 message sizes."""
    chans = {}
    for name, payload, n in (("big", big, n_big), ("small", small, n_small)):
        src, dst = stack.socket_pair()
        chans[name] = rt.channel(src, dst, name=name)
        for _ in range(n):
            src.deliver(build_message(RNG.integers(100, 200, 4),
                                      RNG.integers(1000, 2000, payload)))
    return chans


def test_drr_equalizes_byte_share_across_10_to_1_message_sizes():
    """The fairness property: under DRR, two channels whose messages
    differ 10:1 in size converge to ~equal BYTE shares while both are
    backlogged; a plain round-robin quantum-per-round scheduler hands the
    big flow ~10x the bytes over the same rounds."""
    shares = {}
    for sched in ("drr", "round-robin"):
        stack = _stack(pages_per_shard=512)
        kw = {"quantum_bytes": 1200} if sched == "drr" else {}
        rt = ProxyRuntime(stack, scheduler=sched, **kw)
        chans = _drr_load(stack, rt)
        for _ in range(20):
            rt.step()
        big = chans["big"].stats.logical_bytes
        small = chans["small"].stats.logical_bytes
        # both flows must still be backlogged for the share to be meaningful
        assert chans["big"].ready() and chans["small"].ready()
        shares[sched] = big / max(small, 1)
        rt.run()            # drain so shutdown invariants hold
        rt.shutdown()
        assert stack.alloc.free_pages == stack.alloc.total_pages
    assert 0.5 < shares["drr"] < 2.0, shares
    assert shares["round-robin"] > 4.0, shares


def test_drr_deficit_exposed_and_reset_when_idle():
    stack = _stack()
    rt = ProxyRuntime(stack, scheduler="drr", quantum_bytes=500)
    src, dst = stack.socket_pair()
    ch = rt.channel(src, dst, name="only")
    src.deliver(build_message(np.arange(4), RNG.integers(0, 9, 48)))
    rt.run()
    assert ch.stats.messages == 1
    # the flow went idle: classic DRR forfeits the accumulated credit
    assert ch.stats.deficit == 0.0
    rt.shutdown()


def test_drr_rejects_batched_mode():
    stack = _stack()
    with pytest.raises(AssertionError):
        ProxyRuntime(stack, scheduler="drr", batched=True)


def test_drr_forwards_messages_larger_than_one_quantum():
    """Liveness: a head-of-line message bigger than quantum_bytes needs
    several rounds of credit — accumulating deficit counts as progress,
    so run() must not stop on the first credit-only round."""
    stack = _stack()
    rt = ProxyRuntime(stack, scheduler="drr", quantum_bytes=256)
    src, dst = stack.socket_pair()
    ch = rt.channel(src, dst, name="big")
    payload = RNG.integers(1000, 2000, 1000)
    src.deliver(build_message(np.arange(4), payload))
    rt.run()
    assert ch.stats.messages == 1
    assert np.array_equal(dst.tx_wire()[-1000:], payload)
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_tampered_record_does_not_abort_the_event_loop():
    """One flow delivering a tampered record must not kill the scalar
    scheduler: the channel counts an auth reject and every healthy flow
    keeps forwarding (mirrors the batched path's drop-the-slot)."""
    from repro.core import seal_record

    stack = _stack()
    bad_src, bad_dst = stack.socket_pair("length-prefixed", tls="hw")
    good_src, good_dst = stack.socket_pair()
    rt = ProxyRuntime(stack)
    bad_ch = rt.channel(bad_src, bad_dst, name="bad")
    good_ch = rt.channel(good_src, good_dst, name="good")
    frame = build_message(np.arange(5), RNG.integers(1000, 2000, 40))
    rec = bad_src.tls.seal(frame, bad_src.parser.inner).copy()
    rec[10] ^= 5                     # flip a ciphertext token
    bad_src.deliver(rec)
    good_payload = RNG.integers(1000, 2000, 40)
    good_src.deliver(build_message(np.arange(4), good_payload))
    rt.run()
    assert bad_ch.stats.auth_rejects == 1 and bad_ch.stats.messages == 0
    assert good_ch.stats.messages == 1
    assert np.array_equal(good_dst.tx_wire()[-40:], good_payload)
    # the tampered flow recovers on the next good record
    bad_src.deliver(bad_src.tls.seal(frame, bad_src.parser.inner))
    rt.run()
    assert bad_ch.stats.messages == 1
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_drr_zero_byte_quantum_keeps_credit_charges_once():
    """A quantum that accepts zero logical bytes (a reassembly fragment
    absorbed under a tiny recv_buf) must keep the channel's deficit — the
    message pays its real size exactly once, when it finally transmits
    (the old behaviour pre-charged the estimated size AND the real bytes,
    double-billing fragment- and EAGAIN-prone flows)."""
    stack = _stack()
    rt = ProxyRuntime(stack, scheduler="drr", quantum_bytes=2000)
    src, dst = stack.socket_pair()
    payload = RNG.integers(1000, 2000, 64)
    ch = rt.channel(src, dst, recv_buf=4, name="frag")
    src.deliver(build_message(np.array([101, 7, 7, 7]), payload))
    rt.step()
    # first quantum absorbed a fragment: zero logical bytes, full credit
    assert ch.stats.logical_bytes == 0
    assert ch.stats.deficit == rt.quantum_bytes
    rt.run()
    assert ch.stats.messages == 1
    assert np.array_equal(dst.tx_wire()[-64:], payload)
    assert ch.stats.logical_bytes == 3 + 4 + 64   # charged exactly once
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages
