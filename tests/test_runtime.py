"""ProxyRuntime: one stack multiplexing many connections with mixed parser
policies — readiness scheduling, send budgets, interleaved deliveries,
counter accounting, and teardown."""
import numpy as np
import pytest

from repro.core import (
    Events,
    LibraStack,
    ProxyRuntime,
    build_chunked_message,
    build_delimited_message,
    build_message,
)

RNG = np.random.default_rng(11)


def _stack(**kw):
    kw.setdefault("n_shards", 4)
    kw.setdefault("pages_per_shard", 128)
    kw.setdefault("page_size", 16)
    kw.setdefault("secret", b"rt")
    return LibraStack(**kw)


def test_multiplex_three_connections_mixed_parsers():
    """One stack, ≥3 concurrent flows with different parsers, interleaved
    deliveries; every payload arrives intact and the global CopyCounters
    equal the sum of per-path expectations."""
    stack = _stack()
    rt = ProxyRuntime(stack, tick_every=4)
    n_msgs, meta_n, payload_n, chunk = 6, 4, 48, 24

    chans = {}
    for proto in ("length-prefixed", "delimiter", "chunked"):
        src, dst = stack.socket_pair(proto)
        chans[proto] = (src, dst, rt.channel(src, dst, name=proto))

    payloads = {p: [] for p in chans}
    # interleave deliveries round-robin across connections
    for i in range(n_msgs):
        for proto, (src, _, _) in chans.items():
            meta = RNG.integers(100, 200, meta_n)
            payload = RNG.integers(1000, 2000, payload_n)
            payloads[proto].append(payload)
            if proto == "length-prefixed":
                src.deliver(build_message(meta, payload))
            elif proto == "delimiter":
                src.deliver(build_delimited_message(meta, payload))
            else:
                src.deliver(build_chunked_message(
                    [payload[:chunk], payload[chunk:]]))

    rt.run()

    # every payload crossed intact, in order
    for proto, (_, dst, _) in chans.items():
        wire = dst.tx_wire()
        flat = np.concatenate(payloads[proto])
        if proto == "length-prefixed":
            got = wire.reshape(n_msgs, 3 + meta_n + payload_n)[:, -payload_n:]
        elif proto == "delimiter":
            got = wire.reshape(n_msgs, meta_n + 5 + payload_n)[:, -payload_n:]
        else:
            per = wire.reshape(n_msgs, 2 * (2 + chunk) + 2)[:, :-2]
            got = per.reshape(n_msgs, 2, 2 + chunk)[:, :, 2:]
        assert np.array_equal(got.reshape(-1), flat)

    # counter accounting: each selective message copies its metadata twice
    # (rx + tx), anchors its payload once, zero-copies it once; the chunked
    # terminator (2 tokens) is below the admission threshold -> full copy
    # on both sides.
    c = stack.counters
    lp_meta = 3 + meta_n
    dl_meta = meta_n + 4 + 1
    ck_meta = 2 * 2                       # two chunk headers per message
    assert c.meta_copied == 2 * n_msgs * (lp_meta + dl_meta + ck_meta)
    assert c.full_copied == 2 * n_msgs * 2
    assert c.anchored == 3 * n_msgs * payload_n
    assert c.zero_copied == 3 * n_msgs * payload_n
    assert c.vpi_injected == n_msgs * (1 + 1 + 2)
    assert len(stack.registry) == 0
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_budget_partial_messages_stay_ordered():
    """A budget-truncated message must finish before the next one starts on
    the same flow (TCP ordering per connection)."""
    stack = _stack()
    rt = ProxyRuntime(stack)
    src, dst = stack.socket_pair("length-prefixed")
    ch = rt.channel(src, dst, budget=10)
    p1 = RNG.integers(1000, 2000, 40)
    p2 = RNG.integers(2000, 3000, 40)
    src.deliver(build_message(np.arange(3), p1))
    src.deliver(build_message(np.arange(3), p2))
    rt.run()
    wire = dst.tx_wire()
    assert ch.stats.messages == 2 and ch.stats.partial_sends > 0
    assert np.array_equal(wire[6 : 46], p1)
    assert np.array_equal(wire[-40:], p2)


def test_router_selects_backend_by_header():
    stack = _stack()
    rt = ProxyRuntime(stack)
    src = stack.socket("length-prefixed")
    backends = [stack.socket("length-prefixed") for _ in range(3)]
    rt.channel(src, backends, router=lambda buf, n: backends[int(buf[3]) % 3])
    for tag in (0, 1, 2, 1):
        src.deliver(build_message(np.array([tag]), RNG.integers(0, 9, 32)))
    rt.run()
    lens = [len(b.tx_wire()) for b in backends]
    assert lens == [36, 72, 36]   # 3 hdr + 1 meta + 32 payload per message


def test_priority_scheduler_orders_ready_set():
    stack = _stack()
    rt = ProxyRuntime(stack, scheduler="priority")
    order = []

    def mk_rewrite(name):
        def rewrite(buf, n):
            order.append(name)
            return buf
        return rewrite

    for name, prio in (("lo", 0), ("hi", 5), ("mid", 2)):
        src, dst = stack.socket_pair("length-prefixed")
        rt.channel(src, dst, rewrite=mk_rewrite(name), priority=prio,
                   name=name)
        src.deliver(build_message(np.arange(2), RNG.integers(0, 9, 32)))
    rt.step()
    assert order == ["hi", "mid", "lo"]


def test_round_robin_rotates_service_order():
    stack = _stack()
    rt = ProxyRuntime(stack)
    order = []

    def mk_rewrite(name):
        def rewrite(buf, n):
            order.append(name)
            return buf
        return rewrite

    for name in ("a", "b", "c"):
        src, dst = stack.socket_pair("length-prefixed")
        rt.channel(src, dst, rewrite=mk_rewrite(name), name=name)
        for _ in range(2):
            src.deliver(build_message(np.arange(2), RNG.integers(0, 9, 32)))
    rt.step()
    rt.step()
    assert order[:3] == ["a", "b", "c"]
    assert order[3:] == ["b", "c", "a"]   # rotated start


def test_small_recv_buf_reassembles_before_routing():
    """Regression: a recv_buf smaller than metadata+VPI fragments one
    message across several recv calls; the channel must reassemble it and
    route/forward it exactly once (never hand an empty FAST_PATH fragment
    to the router)."""
    stack = _stack()
    rt = ProxyRuntime(stack)
    src = stack.socket("length-prefixed")
    backends = [stack.socket("length-prefixed") for _ in range(2)]
    routed = []

    def router(buf, n):
        routed.append((len(buf), n))
        return backends[int(buf[3]) % 2]

    ch = rt.channel(src, backends, router=router, recv_buf=4)
    payload = RNG.integers(1000, 2000, 64)
    src.deliver(build_message(np.array([101, 7, 7, 7]), payload))
    rt.run()
    # routed once, with the fully reassembled [meta..., VPI] buffer
    assert routed == [(3 + 4 + 1, 3 + 4 + 64)]
    assert ch.stats.messages == 1
    assert np.array_equal(backends[1].tx_wire()[-64:], payload)
    assert len(backends[0].tx_wire()) == 0
    assert len(stack.registry) == 0


def test_runtime_tick_drives_deferred_teardown():
    stack = _stack(grace_ticks=2)
    rt = ProxyRuntime(stack, tick_every=1)
    src, dst = stack.socket_pair("length-prefixed")
    rt.channel(src, dst)
    src.deliver(build_message(np.arange(3), RNG.integers(0, 9, 64)))
    src.recv(1 << 20)      # anchor, then close with the message in flight
    src.close()
    assert stack.pages_in_use == 4
    # idle steps still advance the clock via tick_every
    for _ in range(4):
        rt.step()
    assert stack.pages_in_use == 0
    assert len(stack.registry) == 0


def test_shared_backend_holds_message_until_send_buffer_frees():
    """Two channels sharing one backend socket: while channel A's message
    is budget-truncated, channel B's message is held (EAGAIN) and retried —
    both arrive whole, never interleaved."""
    stack = _stack()
    rt = ProxyRuntime(stack)
    shared = stack.socket("length-prefixed")
    pa = RNG.integers(1000, 2000, 40)
    pb = RNG.integers(3000, 4000, 40)
    for payload, budget in ((pa, 8), (pb, None)):
        src = stack.socket("length-prefixed")
        rt.channel(src, shared, budget=budget)
        src.deliver(build_message(np.arange(3), payload))
    rt.run()
    wire = shared.tx_wire()
    assert len(wire) == 2 * 46
    # channel A's truncated message finishes before B's is admitted
    assert np.array_equal(wire[6:46], pa)
    assert np.array_equal(wire[-40:], pb)
    assert sum(c.stats.messages for c in rt.channels) == 2


def test_trickled_delivery_waits_for_frame_then_anchors():
    """A message arriving in small network chunks must be forwarded as ONE
    selectively-copied message once framable — never as raw fragments."""
    stack = _stack()
    rt = ProxyRuntime(stack)
    src, dst = stack.socket_pair("length-prefixed")
    ch = rt.channel(src, dst)
    payload = RNG.integers(1000, 2000, 32)
    msg = build_message(np.arange(4), payload)
    for lo in range(0, len(msg), 5):      # 5-token trickles
        src.deliver(msg[lo : lo + 5])
        rt.step()
    rt.run()
    assert ch.stats.messages == 1
    assert stack.counters.zero_copied == 32      # anchored, not full-copied
    assert np.array_equal(dst.tx_wire()[-32:], payload)


def test_client_close_mid_truncated_send_still_drains():
    """Regression: a client closing while its message is budget-truncated
    must not strand the backend — the frame finishes transmitting (§A.4)
    and other channels sharing the backend proceed."""
    stack = _stack(grace_ticks=3)
    rt = ProxyRuntime(stack, tick_every=1)
    shared = stack.socket("length-prefixed")
    pa = RNG.integers(1000, 2000, 40)
    pb = RNG.integers(3000, 4000, 40)
    a = stack.socket("length-prefixed")
    ch_a = rt.channel(a, shared, budget=16)
    a.deliver(build_message(np.arange(3), pa))
    rt.step()                     # truncated: backend pending
    assert shared.pending_send is not None
    a.close()                     # client vanishes mid-send
    b = stack.socket("length-prefixed")
    rt.channel(b, shared)
    b.deliver(build_message(np.arange(3), pb))
    rt.run()
    wire = shared.tx_wire()
    assert shared.pending_send is None
    assert np.array_equal(wire[6:46], pa)    # A's frame finished first
    assert np.array_equal(wire[-40:], pb)    # then B flowed
    stack.drain()
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_shutdown_reclaims_everything():
    stack = _stack()
    rt = ProxyRuntime(stack)
    for proto in ("length-prefixed", "delimiter"):
        src, dst = stack.socket_pair(proto)
        rt.channel(src, dst)
        src.deliver(build_message(np.arange(3), RNG.integers(0, 9, 48))
                    if proto == "length-prefixed" else
                    build_delimited_message(np.arange(3),
                                            RNG.integers(0, 9, 48)))
        src.recv(1 << 20)  # leave a message half-proxied
    rt.shutdown()
    assert all(s.closed for ch in rt.channels for s in [ch.src] + ch.dsts)
    assert stack.alloc.free_pages == stack.alloc.total_pages
    assert len(stack.registry) == 0
