"""Multi-worker LibraCluster: RSS-style flow steering, the cross-worker
VPI grant/migration protocol (zero-copy grants + the counted one-copy
fallback), the §A.4 teardown interleave across workers, and the
work-stealing cluster scheduler — all held byte- and counter-identical to
a single-stack run of the same workload."""
import numpy as np
import pytest

from repro.core import (
    ClusterRuntime,
    LibraCluster,
    LibraStack,
    ProxyRuntime,
    SteeringPolicy,
    VpiRegistry,
    build_delimited_message,
    build_message,
)

RNG = np.random.default_rng(23)

STACK_KW = dict(n_shards=4, pages_per_shard=128, page_size=16)


def _cluster(n_workers=2, **kw):
    for k, v in STACK_KW.items():
        kw.setdefault(k, v)
    kw.setdefault("secret", b"cl")
    return LibraCluster(n_workers, **kw)


def _workload(n_chans, n_msgs, seed=5, payload=40, builder=build_message):
    rng = np.random.default_rng(seed)
    return [[builder(rng.integers(100, 200, 4),
                     rng.integers(1000, 2000, payload))
             for _ in range(n_msgs)]
            for _ in range(n_chans)]


def _run_single(frames, **rt_kw):
    stack = LibraStack(secret=b"cl", **STACK_KW)
    rt = ProxyRuntime(stack, **rt_kw)
    dsts = []
    for chan_frames in frames:
        src, dst = stack.socket_pair()
        rt.channel(src, dst)
        dsts.append(dst)
        for f in chan_frames:
            src.deliver(f)
    rt.run()
    wires = [d.tx_wire() for d in dsts]
    snap = stack.counters.snapshot()
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages
    return wires, snap


def _run_cluster(frames, cross_fraction, n_workers=2, cluster=None, **rt_kw):
    """Channel i's src lands on worker i % W; a ``cross_fraction`` prefix
    of channels places dst on the NEXT worker (cross-worker flows)."""
    cl = cluster if cluster is not None else _cluster(n_workers)
    crt = ClusterRuntime(cl, **rt_kw)
    w = len(cl.workers)
    dsts = []
    for i, chan_frames in enumerate(frames):
        sw = i % w
        dw = (sw + 1) % w if i < cross_fraction * len(frames) else sw
        src = cl.socket(worker=sw)
        dst = cl.socket(worker=dw)
        crt.channel(src, dst)
        dsts.append(dst)
        for f in chan_frames:
            src.deliver(f)
    crt.run()
    wires = [d.tx_wire() for d in dsts]
    snap = cl.counters_aggregate().snapshot()
    crt.shutdown()
    assert cl.pages_in_use == 0
    return cl, wires, snap


# ---------------------------------------------------------------------------
# steering
# ---------------------------------------------------------------------------

def test_steering_same_flow_same_worker_across_reregistration():
    """The consistent-hash property test: the same flow key maps to the
    same worker on every lookup AND on a freshly-built policy with the
    same parameters (placement survives re-registration)."""
    flows = [("10.0.0.%d" % (i % 7), 1000 + i, "backend", 80 + i % 3)
             for i in range(200)]
    a = SteeringPolicy(4)
    b = SteeringPolicy(4)
    for f in flows:
        w = a.worker_for(f)
        assert a.worker_for(f) == w            # stable across lookups
        assert b.worker_for(f) == w            # stable across registration
    # rough balance: no worker owns more than 60% of flows
    assert max(a.stats["per_worker"]) < 0.6 * len(a.placements) * 2


def test_steering_resize_moves_a_minority_of_flows():
    """Consistent hashing's point: growing the ring re-steers ~1/N of the
    flows, not all of them."""
    pol = SteeringPolicy(4)
    flows = [("flow", i) for i in range(300)]
    for f in flows:
        pol.worker_for(f)
    moved = pol.resteer(n_workers=5)
    assert 0 < moved < 0.5 * len(flows)
    assert pol.stats["resteers"] == 1 and pol.stats["moved"] == moved


def test_app_defined_steering_and_socket_pair_affinity():
    calls = []

    def rsd(flow, n):
        calls.append(flow)
        return hash(flow) % n

    cl = _cluster(3, steering="app", app_fn=rsd)
    for i in range(12):
        flow = ("conn", i)
        src, dst = cl.socket_pair(flow=flow)
        assert src.worker_id == dst.worker_id == rsd(flow, 3)
    assert len(calls) >= 12
    assert cl.steering.stats["steered"] >= 12


# ---------------------------------------------------------------------------
# cross-worker forwarding: the acceptance identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cross_fraction", [0.0, 0.5, 1.0])
@pytest.mark.parametrize("batched", [False, True])
def test_cluster_byte_and_counter_identical_to_single_stack(
        cross_fraction, batched):
    """At ANY cross-worker fraction, scalar or batched, the cluster
    forwards byte-identical wires and its aggregate CopyCounters equal the
    single-stack run — zero-copy grants ride on the side (counted
    separately, never in the Fig. 9 categories)."""
    frames = _workload(n_chans=6, n_msgs=4)
    ref_wires, ref_snap = _run_single(frames, batched=batched)
    cl, wires, snap = _run_cluster(frames, cross_fraction, batched=batched)
    assert snap == ref_snap
    for a, b in zip(ref_wires, wires):
        assert np.array_equal(a, b)
    expect_cross = cross_fraction > 0
    assert (cl.stats["grants"] > 0) == expect_cross
    assert cl.stats["copies"] == 0
    agg = cl.counters_aggregate()
    assert (agg.cross_worker_grants > 0) == expect_cross
    assert agg.cross_worker_copied == 0


def test_cross_worker_copied_fallback_when_dst_pool_above_watermark():
    """A congested destination pool refuses the zero-copy import: the
    payload is gathered ONCE out of the owner's pool (counted in
    cross_worker_copied), the owner's anchor is released at handoff, and
    the wire bytes are still identical."""
    frames = _workload(n_chans=4, n_msgs=3)
    ref_wires, ref_snap = _run_single(frames)
    cl = _cluster(2)
    cl.workers[1].high_watermark = 0.0     # w1 "congested" from the start
    crt = ClusterRuntime(cl)
    dsts = []
    for chan_frames in frames:             # every flow src=w0 -> dst=w1
        src = cl.socket(worker=0)
        dst = cl.socket(worker=1)
        crt.channel(src, dst)
        dsts.append(dst)
        for f in chan_frames:
            src.deliver(f)
    crt.run()
    wires = [d.tx_wire() for d in dsts]
    snap = cl.counters_aggregate().snapshot()
    assert snap == ref_snap
    for a, b in zip(ref_wires, wires):
        assert np.array_equal(a, b)
    assert cl.stats["copies"] > 0 and cl.stats["grants"] == 0
    agg = cl.counters_aggregate()
    assert agg.cross_worker_copied == cl.stats["copied_tokens"] > 0
    crt.shutdown()
    assert cl.pages_in_use == 0


def test_grant_outlives_owner_teardown_grace():
    """§A.4 interleave across workers: the owner socket closes and its
    whole grace period expires while a grant is outstanding — the granted
    payload stays readable (the grant's pin ref), and completing the
    grantee's send releases the last reference."""
    cl = _cluster(2)
    w0, w1 = cl.workers
    src = cl.socket(worker=0)
    dst = cl.socket(worker=1)
    meta = RNG.integers(100, 200, 4)
    payload = RNG.integers(1000, 2000, 40)
    src.deliver(build_message(meta, payload))
    buf, n = src.recv(1 << 20)
    vpi = next(iter(src.connection.anchored))
    pages_used = w0.pages_in_use
    assert pages_used > 0

    granted = cl.grant_into(w1, vpi)
    assert granted is not None and cl.stats["grants"] == 1
    assert w0.alloc.granted_out_pages == pages_used

    # owner closes; its ENTIRE grace period expires: the expiry drops the
    # owner's own page references...
    src.close()
    freed = w0.drain()
    assert freed == pages_used
    assert vpi not in w0.registry          # owner entry fully gone
    # ...but the grant's pin reference keeps the pages resident
    assert w0.pages_in_use == pages_used

    # the grantee can still transmit the payload, bytes intact (recv's
    # buffer is [metadata..., VPI]: the handle sits in the last slot)
    out = buf.copy()
    out[-1] = VpiRegistry.to_token(granted)
    sent = dst.send(out)
    assert sent == (len(buf) - 1) + len(payload)
    wire = dst.tx_wire()
    assert np.array_equal(wire[-len(payload):], payload)
    # completion dropped the last reference: owner pool fully reclaimed
    assert w0.pages_in_use == 0
    assert w0.alloc.granted_out_pages == 0
    assert w1.pages_in_use == 0


def test_grant_completion_with_live_owner_cleans_both_sides():
    """The common case: owner stays open; grant completion performs the
    exact single-stack cleanup on the owner (entry released, pages freed,
    RX machine reset) plus the grant teardown on the grantee."""
    cl = _cluster(2)
    w0, w1 = cl.workers
    src = cl.socket(worker=0)
    dst = cl.socket(worker=1)
    payload = RNG.integers(1000, 2000, 40)
    src.deliver(build_message(RNG.integers(100, 200, 4), payload))
    buf, _ = src.recv(1 << 20)
    src.forward(dst, buf)                  # adoption happens inside
    assert cl.stats["grants"] == 1
    assert np.array_equal(dst.tx_wire()[-len(payload):], payload)
    assert w0.pages_in_use == 0 and len(w0.registry) == 0
    assert len(w1.registry) == 0
    assert not src.connection.anchored
    # cross-datapath cleanup reached the src RX machine (can recv again)
    src.deliver(build_message(RNG.integers(100, 200, 4), payload))
    buf2, n2 = src.recv(1 << 20)
    assert n2 > 0


def test_budget_truncated_cross_worker_send_resumes_and_completes():
    """A cross-worker message truncated by the send budget resumes from
    the cumulative offset exactly like a local one — including when the
    owner tears down mid-flight."""
    frames = _workload(n_chans=2, n_msgs=2, payload=60)
    ref_wires, ref_snap = _run_single(frames, )
    cl, wires, snap = _run_cluster(frames, 1.0, batched=False)
    assert snap == ref_snap  # sanity: full-message runs agree

    cl2 = _cluster(2)
    src = cl2.socket(worker=0)
    dst = cl2.socket(worker=1)
    payload = RNG.integers(1000, 2000, 60)
    src.deliver(build_message(RNG.integers(100, 200, 4), payload))
    buf, _ = src.recv(1 << 20)
    n = src.forward(dst, buf, budget=16)
    assert 0 < n < len(payload)
    src.close()
    cl2.workers[0].drain()                 # owner's grace fully expires
    while dst.pending_send is not None:
        dst.send(budget=16)
    assert np.array_equal(dst.tx_wire()[-len(payload):], payload)
    cl2.drain()
    assert cl2.pages_in_use == 0


# ---------------------------------------------------------------------------
# cluster scheduling: work stealing
# ---------------------------------------------------------------------------

def test_work_stealing_counter_identity_vs_pinned():
    """All flows pinned to worker 0 (worst-case imbalance): the stealing
    run services some quanta on idle workers — and produces EXACTLY the
    same aggregate counters, messages, and wire bytes as the pinned run."""
    frames = _workload(n_chans=6, n_msgs=4)

    def run(stealing):
        cl = _cluster(3, steering="app", app_fn=lambda flow, n: 0)
        crt = ClusterRuntime(cl, work_stealing=stealing)
        dsts = []
        for chan_frames in frames:
            src, dst = cl.socket_pair()
            crt.channel(src, dst)
            dsts.append(dst)
            for f in chan_frames:
                src.deliver(f)
        crt.run()
        wires = [d.tx_wire() for d in dsts]
        snap = cl.counters_aggregate().snapshot()
        msgs = crt.messages_forwarded()
        stolen = crt.stats["stolen_quanta"]
        crt.shutdown()
        return wires, snap, msgs, stolen

    wires_p, snap_p, msgs_p, stolen_p = run(False)
    wires_s, snap_s, msgs_s, stolen_s = run(True)
    assert stolen_p == 0 and stolen_s > 0
    assert snap_s == snap_p and msgs_s == msgs_p
    for a, b in zip(wires_p, wires_s):
        assert np.array_equal(a, b)


def test_run_parallel_completes_and_reports_per_worker_times():
    frames = _workload(n_chans=4, n_msgs=3, builder=build_delimited_message)
    cl = _cluster(2)
    crt = ClusterRuntime(cl, work_stealing=False)
    for i, chan_frames in enumerate(frames):
        src, dst = cl.socket_pair("delimiter", flow=("f", i))
        crt.channel(src, dst)
        for f in chan_frames:
            src.deliver(f)
    msgs, times = crt.run_parallel()
    assert msgs == sum(len(c) for c in frames)
    assert len(times) == 2 and all(t >= 0 for t in times)
    crt.shutdown()
    assert cl.pages_in_use == 0


def test_abandoned_grant_reclaimed_at_shutdown():
    """A grant whose transmit never happens (message dropped, grantee
    closed) must not pin the owner's pages forever: ClusterRuntime
    shutdown reclaims abandoned handoff entries and the pools drain."""
    cl = _cluster(2)
    crt = ClusterRuntime(cl)
    src = cl.socket(worker=0)
    dst = cl.socket(worker=1)
    crt.channel(src, dst)
    src.deliver(build_message(RNG.integers(100, 200, 4),
                              RNG.integers(1000, 2000, 40)))
    buf, _ = src.recv(1 << 20)
    vpi = next(iter(src.connection.anchored))
    granted = cl.grant_into(cl.workers[1], vpi)
    assert granted is not None          # grant outstanding, never sent
    crt.shutdown()
    assert cl.stats["grants_reclaimed"] == 1
    assert cl.pages_in_use == 0
    for w in cl.workers:
        assert w.alloc.free_pages == w.alloc.total_pages
        assert w.alloc.granted_out_pages == 0
        assert len(w.registry) == 0


def test_resteer_to_app_mode_without_callable_fails_cleanly():
    pol = SteeringPolicy(4)
    for i in range(10):
        pol.worker_for(("f", i))
    placements = dict(pol.placements)
    with pytest.raises(ValueError):
        pol.resteer(mode="app")
    # nothing was half-mutated: same mode, same placements, no resteer
    assert pol.mode == "hash" and pol.stats["resteers"] == 0
    assert pol.placements == placements
    assert pol.resteer(mode="app", app_fn=lambda f, n: 0) >= 0


def test_chained_grant_flattens_to_root_owner():
    """Re-granting a granted VPI to a third worker must pin and reference
    the ROOT pool — completion releases the true owner, and the payload
    bytes come from the pool that actually holds them."""
    cl = _cluster(3)
    w0, w1, w2 = cl.workers
    src = cl.socket(worker=0)
    dst = cl.socket(worker=2)
    meta = RNG.integers(100, 200, 4)
    payload = RNG.integers(1000, 2000, 40)
    src.deliver(build_message(meta, payload))
    buf, _ = src.recv(1 << 20)
    vpi0 = next(iter(src.connection.anchored))
    pages = w0.pages_in_use
    vpi1 = cl.grant_into(w1, vpi0)          # w0 -> w1
    vpi2 = cl.grant_into(w2, vpi1)          # w1 -> w2 (chained)
    e2 = w2.registry.peek(vpi2)
    assert e2.pool_id == w0.pool.pool_id    # flattened to the root pool
    assert e2.grant.owner_vpi == vpi0       # and the root entry
    assert w0.alloc.granted_out_pages == 2 * pages   # both grants pin w0
    assert w1.alloc.granted_out_pages == 0
    # w2 transmits: correct bytes, root cleaned up
    out = buf.copy()
    out[-1] = VpiRegistry.to_token(vpi2)
    dst.send(out)
    assert np.array_equal(dst.tx_wire()[-40:], payload)
    assert vpi0 not in w0.registry
    # the middleman's grant is now dangling-by-design; shutdown reclaims
    cl.close_all()
    cl.drain()
    cl.reclaim_abandoned_grants()
    for w in cl.workers:
        assert w.alloc.free_pages == w.alloc.total_pages
        assert w.alloc.granted_out_pages == 0


def test_batched_cluster_counts_auth_rejects_on_the_channel():
    """Batched parity for tamper telemetry: the dropped slot is counted on
    the owning channel, as the scalar RecordAuthError path does."""
    stack = LibraStack(secret=b"cl", **STACK_KW)
    rt = ProxyRuntime(stack, batched=True)
    src, dst = stack.socket_pair("length-prefixed", tls="hw")
    ch = rt.channel(src, dst, name="bad")
    frame = build_message(np.arange(5), RNG.integers(1000, 2000, 40))
    rec = src.tls.seal(frame, src.parser.inner).copy()
    rec[9] ^= 7
    src.deliver(rec)
    rt.run()
    assert ch.stats.auth_rejects == 1 and ch.stats.messages == 0
    # and the flow recovers
    src.deliver(src.tls.seal(frame, src.parser.inner))
    rt.run()
    assert ch.stats.messages == 1
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_run_parallel_threads_byte_and_counter_identical():
    """run_parallel(threads=True) — real worker threads — forwards exactly
    the same messages, wire bytes and aggregate counters as the emulated
    per-worker executor. Pool headroom is ample so the grant-vs-copy
    watermark never trips: the decision sequence is deterministic even
    though thread interleaving reorders VPI-ID allocation."""
    frames = _workload(n_chans=9, n_msgs=4)

    def run(threads):
        cl = _cluster(3, pages_per_shard=512)
        crt = ClusterRuntime(cl, work_stealing=False)
        w = len(cl.workers)
        dsts = []
        for i, chan_frames in enumerate(frames):
            sw = i % w
            dw = (sw + 1) % w if i < 4 else sw
            src = cl.socket(worker=sw)
            dst = cl.socket(worker=dw)
            crt.channel(src, dst)
            dsts.append(dst)
            for f in chan_frames:
                src.deliver(f)
        msgs, times = crt.run_parallel(threads=threads)
        wires = [d.tx_wire() for d in dsts]
        snap = cl.counters_aggregate().snapshot()
        crt.shutdown()
        assert cl.pages_in_use == 0
        assert len(times) == w and all(t >= 0 for t in times)
        return msgs, wires, snap

    msgs_e, wires_e, snap_e = run(False)
    msgs_t, wires_t, snap_t = run(True)
    assert msgs_t == msgs_e == sum(len(c) for c in frames)
    assert snap_t == snap_e
    for a, b in zip(wires_e, wires_t):
        assert np.array_equal(a, b)
