"""Sharding rule resolution + HLO analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.common.sharding import (
    DEFAULT_RULES,
    AxisType,
    abstract_mesh,
    fsdp2d_rules,
    spec_for,
    tree_shardings,
)
from repro.roofline.hlo_analysis import (
    analyze_hlo_text,
    collective_bytes,
    parse_hlo,
    shape_bytes,
)


def _mesh(shape=(2, 4), axes=("data", "model")):
    return abstract_mesh(shape, axes)


class TestSpecFor:
    def test_divisible_shards(self):
        m = _mesh()
        assert spec_for((8, 64), ("batch", None), m) == P("data", None)
        assert spec_for((16, 32), ("fsdp", "tensor"), m) == P("data", "model")

    def test_non_divisible_replicates(self):
        m = _mesh()
        # 6 % 4 != 0 -> tensor dim replicated rather than erroring
        assert spec_for((16, 6), ("fsdp", "tensor"), m) == P("data", None)
        assert spec_for((3, 6), ("fsdp", "tensor"), m) == P(None, None)

    def test_axis_used_once(self):
        m = _mesh()
        # both dims map to 'model': only the first claims it
        spec = spec_for((8, 8), ("tensor", "act_heads"), m)
        assert spec == P("model", None)

    def test_multi_axis_claim(self):
        m = _mesh((2, 4), ("pod", "data"))
        spec = spec_for((8, 4), ("batch", None), m)
        assert spec == P(("pod", "data"), None)

    def test_fsdp2d_prefix_divisibility(self):
        m = _mesh((2, 16, 16), ("pod", "data", "model"))
        r = fsdp2d_rules()
        # batch 256 claims (data, model) = 256 but NOT pod (256 % 512 != 0)
        assert spec_for((256, 128), ("batch", None), m, r) == \
            P(("data", "model"), None)


class TestHloAnalyzer:
    def test_shape_bytes(self):
        assert shape_bytes("f32[4,8]{1,0}") == 128
        assert shape_bytes("bf16[10]") == 20
        assert shape_bytes("(f32[2,2]{1,0}, s32[3])") == 28
        assert shape_bytes("pred[7]") == 7

    def test_collective_bytes_ring_model(self):
        import re

        from repro.roofline.hlo_analysis import Instruction

        inst = Instruction(
            "ag", "f32[64,64]{1,0}", "all-gather", ["x"],
            '%ag = f32[64,64]{1,0} all-gather(%x), replica_groups=[4,8]<=[32]')
        kind, naive, ring = collective_bytes(inst)
        assert kind == "all-gather"
        assert naive == 64 * 64 * 4 // 8
        assert ring == 64 * 64 * 4 * 7 // 8

    def test_scan_trip_count_correction(self):
        """FLOPs of a scanned matmul must scale with scan length."""
        def f(w, x):
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            return jax.lax.scan(body, x, w)[0].sum()

        w = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
        x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        txt = jax.jit(f).lower(w, x).compile().as_text()
        costs = analyze_hlo_text(txt)
        expected = 7 * 2 * 16 * 32 * 32
        assert abs(costs.flops - expected) / expected < 0.05
        assert 7 in costs.trip_counts

    def test_parse_computations(self):
        def f(x):
            return jnp.sin(x) @ x.T

        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile().as_text()
        comps = parse_hlo(txt)
        assert len(comps) >= 1
        costs = analyze_hlo_text(txt)
        assert costs.flops >= 2 * 32 * 32 * 32


class TestModelFlops:
    def test_dense_train_close_to_6nd(self):
        from repro.configs import get_config
        from repro.common.types import TRAIN_4K
        from repro.roofline.analysis import model_flops, matmul_params

        cfg = get_config("phi3-mini-3.8b")
        mf = model_flops(cfg, TRAIN_4K)
        n = matmul_params(cfg)
        tokens = 256 * 4096
        assert mf > 6 * n * tokens  # attention + logits on top
        assert mf < 6 * n * tokens * 1.8
