"""LibraStack/LibraSocket facade: parity with the explicit-plumbing free
functions, partial sends under send budgets, pool-exhaustion drain, and
tick-driven deferred teardown — the POSIX surface of the redesign."""
import numpy as np
import pytest

from repro.core import (
    AnchorPool,
    Connection,
    CopyCounters,
    Events,
    LengthPrefixedParser,
    LibraStack,
    St,
    TokenPool,
    VpiRegistry,
    build_message,
    libra_recv,
    libra_send,
)

RNG = np.random.default_rng(3)


def _mk_stack(**kw):
    kw.setdefault("n_shards", 4)
    kw.setdefault("pages_per_shard", 64)
    kw.setdefault("page_size", 16)
    kw.setdefault("secret", b"t")
    return LibraStack(**kw)


def _msg(meta_n=5, payload_n=64):
    meta = RNG.integers(100, 200, meta_n)
    payload = RNG.integers(1000, 2000, payload_n)
    return build_message(meta, payload), meta, payload


# ---------------------------------------------------------------------------
# parity with the compatibility layer
# ---------------------------------------------------------------------------

def test_facade_parity_with_free_functions():
    """The facade must be byte- and counter-identical to hand-threading
    pool/registry/counters through libra_recv/libra_send."""
    msg, meta, payload = _msg()

    # explicit plumbing (compatibility layer)
    alloc = AnchorPool(4, 64, 16)
    pool = TokenPool(alloc)
    reg = VpiRegistry(secret=b"t")
    counters = CopyCounters()
    cin = Connection(LengthPrefixedParser(), reg, min_payload=8)
    cout = Connection(LengthPrefixedParser(), reg, min_payload=8)
    cin.deliver(msg)
    buf_f, n_f = libra_recv(cin, 1 << 20, pool, reg, counters)
    sent_f = libra_send(cin, cout, buf_f, pool, reg, counters)

    # facade
    stack = _mk_stack()
    src, dst = stack.socket_pair("length-prefixed")
    src.deliver(msg)
    buf_s, n_s = src.recv(1 << 20)
    sent_s = src.forward(dst, buf_s)

    assert n_s == n_f and sent_s == sent_f
    assert len(buf_s) == len(buf_f)
    assert np.array_equal(buf_s[:-1], buf_f[:-1])  # same meta; VPIs differ
    assert np.array_equal(cout.tx_stream[-1], dst.tx_wire())
    for field in ("meta_copied", "full_copied", "anchored", "zero_copied",
                  "vpi_injected", "allocs"):
        assert getattr(stack.counters, field) == getattr(counters, field), field
    assert len(stack.registry) == 0
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_send_resolves_anchor_owner_via_vpi():
    """POSIX-shaped send on the egress socket alone: the stack resolves the
    anchoring connection from the embedded VPI (global-map analogue)."""
    stack = _mk_stack()
    msg, meta, payload = _msg()
    src = stack.socket("length-prefixed")
    dst = stack.socket("length-prefixed")
    src.deliver(msg)
    buf, n = src.recv(1 << 20)
    sent = dst.send(buf)   # no forward(), no explicit src
    assert sent == n
    assert np.array_equal(dst.tx_wire()[-len(payload):], payload)
    assert src.connection.rx_machine.state is St.DEFAULT  # cross-path reset
    assert len(stack.registry) == 0


# ---------------------------------------------------------------------------
# partial sends (send budgets)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [1, 7, 10, 71, 72, 1000])
def test_partial_send_budget_resumes_exactly(budget):
    stack = _mk_stack()
    msg, meta, payload = _msg(meta_n=5, payload_n=64)
    logical = 3 + 5 + 64
    src, dst = stack.socket_pair("length-prefixed")
    src.deliver(msg)
    buf, _ = src.recv(1 << 20)
    total = src.forward(dst, buf, budget=budget)
    calls = 1
    while dst.pending_send is not None:
        n = dst.send(budget=budget)
        assert n > 0
        total += n
        calls += 1
        assert calls < 200
    assert total == logical
    wire = dst.tx_wire()
    assert len(wire) == logical
    assert np.array_equal(wire[-64:], payload)
    # metadata and payload counted once regardless of how many send calls
    assert stack.counters.zero_copied == 64
    assert stack.counters.meta_copied == 8 + 8  # rx meta + tx meta
    assert len(stack.registry) == 0
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_new_buffer_while_pending_raises_eagain():
    """Regression: a second message on a socket with a budget-truncated
    send pending must be refused (EAGAIN analogue), not silently swallowed
    into the first message's continuation."""
    stack = _mk_stack()
    msg1, _, p1 = _msg()
    msg2, _, _ = _msg()
    src1, dst = stack.socket_pair("length-prefixed")
    src2 = stack.socket("length-prefixed")
    src1.deliver(msg1)
    src2.deliver(msg2)
    buf1, _ = src1.recv(1 << 20)
    buf2, _ = src2.recv(1 << 20)
    src1.forward(dst, buf1, budget=8)       # truncated -> pending
    with pytest.raises(BlockingIOError):
        src2.forward(dst, buf2)
    # the pending message still completes untouched
    while dst.pending_send is not None:
        dst.send(budget=8)
    assert np.array_equal(dst.tx_wire()[-64:], p1)
    src2.close()
    stack.drain()


def test_src_close_mid_partial_send_completes_from_staged_frame():
    """Regression: closing the anchoring socket while its message is
    half-sent (§A.4 teardown) must not crash the continuation; the staged
    frame finishes the wire and pages are freed exactly once (by teardown
    expiry, not the send completion)."""
    stack = _mk_stack(grace_ticks=2)
    msg, _, payload = _msg(payload_n=64)
    src, dst = stack.socket_pair("length-prefixed")
    src.deliver(msg)
    buf, _ = src.recv(1 << 20)
    total = src.forward(dst, buf, budget=10)
    src.close()                       # anchor enters the grace period
    while dst.pending_send is not None:
        n = dst.send(budget=10)
        assert n > 0
        total += n
    assert total == 3 + 5 + 64
    assert np.array_equal(dst.tx_wire()[-64:], payload)
    stack.drain()
    # exactly once: a double free would push free_pages past total
    assert stack.alloc.free_pages == stack.alloc.total_pages
    assert len(stack.registry) == 0


def test_forward_to_baseline_socket_completes_as_full_copy():
    """Regression: forwarding a selective-copy frame to a baseline socket
    (admission threshold above any payload -> DEFAULT full copy) must
    complete at the frame's byte length, not wedge on the registry's
    logical length."""
    stack = _mk_stack()
    msg, _, _ = _msg()
    src = stack.socket("length-prefixed")
    dst = stack.socket("length-prefixed", min_payload=1 << 30)
    src.deliver(msg)
    buf, _ = src.recv(1 << 20)
    sent = src.forward(dst, buf)
    assert sent == len(buf)
    assert dst.pending_send is None          # completed, not stuck at 8/72
    # the socket still accepts new messages
    assert dst.send(np.arange(4)) == 4


def test_header_only_frame_then_fastpath_message():
    """Regression: a frame that parses to a payload but carries no VPI slot
    leaves METADATA_PARSED behind; the next real message must fast-path
    cleanly instead of crashing on a phantom resume."""
    stack = _mk_stack()
    src, dst = stack.socket_pair("length-prefixed")
    header_only = np.array([17, 4, 50, 9, 9, 9, 9], np.int64)  # claims 50
    assert dst.send(header_only) == len(header_only)
    assert dst.pending_send is None
    msg, _, payload = _msg()
    src.deliver(msg)
    buf, _ = src.recv(1 << 20)
    assert src.forward(dst, buf) == 3 + 5 + 64   # no TypeError, fast path
    assert np.array_equal(dst.tx_wire()[-64:], payload)
    assert len(stack.registry) == 0


def test_unresolvable_send_never_resets_own_rx_machine():
    """Regression: completing a send with no live anchor owner must not
    reset the sending socket's own in-flight RX state (the fallback used
    to default the 'source' to self)."""
    stack = _mk_stack(grace_ticks=5)
    # socket S is mid-recv: message anchored but logical remainder capped
    s = stack.socket("length-prefixed")
    msg, _, payload = _msg(meta_n=5, payload_n=64)
    s.deliver(msg)
    s.recv(10)                      # logical capped: RX stays FAST_PATH
    assert s.connection.rx_machine.state is St.FAST_PATH
    # meanwhile S transmits a frame whose anchor was torn down elsewhere
    other = stack.socket("length-prefixed")
    msg2, _, _ = _msg()
    other.deliver(msg2)
    frame, _ = other.recv(1 << 20)
    other.close()                   # anchor -> TEARDOWN
    s.send(frame)                   # completes via the teardown fallback
    # S's own receive state survived; the remainder is still recoverable
    assert s.connection.rx_machine.state is St.FAST_PATH
    _, more = s.recv(1 << 20)
    assert more == (3 + 5 + 64) - 10
    stack.drain()


def test_stale_vpi_frame_does_not_wedge_next_message():
    """Regression: a frame whose VPI was already released (double-forward)
    claims a payload that never follows; the next message on the socket
    must still fast-path instead of being swallowed by the stale bypass."""
    stack = _mk_stack()
    msg, _, payload = _msg()
    src, dst = stack.socket_pair("length-prefixed")
    src.deliver(msg)
    buf, _ = src.recv(1 << 20)
    src.forward(dst, buf)                 # completes, releases the VPI
    sent = dst.send(buf.copy())           # same frame again: stale handle
    assert sent == len(buf)
    # a fresh selective-copy message is NOT absorbed into the stale bypass
    src2 = stack.socket("length-prefixed")
    msg2, _, payload2 = _msg()
    src2.deliver(msg2)
    buf2, _ = src2.recv(1 << 20)
    before = stack.counters.zero_copied
    assert src2.forward(dst, buf2) == 3 + 5 + 64
    assert stack.counters.zero_copied == before + 64   # fast path, not full copy
    assert dst.pending_send is None
    assert np.array_equal(dst.tx_wire()[-64:], payload2)


def test_src_close_before_first_send_completes_frame():
    """Regression: forwarding a [meta, VPI] frame whose anchor entered the
    §A.4 grace period (src closed BEFORE the first send) must transmit the
    frame and complete — not wedge the TX machine waiting for payload
    bytes that can never arrive."""
    stack = _mk_stack(grace_ticks=3)
    msg, _, payload = _msg()
    src, dst = stack.socket_pair("length-prefixed")
    src.deliver(msg)
    buf, _ = src.recv(1 << 20)
    src.close()                      # anchor -> TEARDOWN before any send
    sent = dst.send(buf)
    assert sent == len(buf)          # the frame itself, nothing phantom
    assert dst.pending_send is None
    assert dst.connection.tx_machine.state is St.DEFAULT  # completed, not wedged
    # a healthy selective-copy message on the same socket still fast-paths
    msg2, _, payload2 = _msg()
    src2 = stack.socket("length-prefixed")
    src2.deliver(msg2)
    buf2, _ = src2.recv(1 << 20)
    assert src2.forward(dst, buf2) == 3 + 5 + 64
    assert np.array_equal(dst.tx_wire()[-64:], payload2)
    stack.drain()
    assert stack.alloc.free_pages == stack.alloc.total_pages
    assert len(stack.registry) == 0


def test_socket_default_send_budget():
    """A socket-level send_budget applies when the call passes none."""
    stack = _mk_stack()
    msg, _, payload = _msg()
    src, dst = stack.socket_pair("length-prefixed")
    dst.send_budget = 16
    src.deliver(msg)
    buf, _ = src.recv(1 << 20)
    n = src.forward(dst, buf)
    assert n == 16 and dst.pending_send is not None
    while dst.pending_send is not None:
        dst.send()
    assert np.array_equal(dst.tx_wire()[-64:], payload)


# ---------------------------------------------------------------------------
# pool exhaustion through the facade (+ the accounting regression)
# ---------------------------------------------------------------------------

def test_pool_exhaustion_drains_through_facade():
    stack = _mk_stack(n_shards=1, pages_per_shard=2, page_size=16)
    meta = RNG.integers(100, 200, 2)
    payload = RNG.integers(1000, 2000, 200)  # needs 13 pages > 2
    sock = stack.socket("length-prefixed")
    sock.deliver(build_message(meta, payload))
    parts, total = [], 0
    for _ in range(50):
        buf, n = sock.recv(64)
        parts.append(buf)
        total += n
        if sock.rx_available() == 0:
            break
    got = np.concatenate(parts)
    assert np.array_equal(got[-200:], payload)
    assert len(stack.registry) == 0


def test_exhaustion_counts_meta_and_payload_once():
    """Regression: the §A.1 overflow path used to count the already-copied
    metadata a second time as full copy. Copies must partition exactly:
    meta tokens -> meta_copied, payload tokens -> full_copied."""
    stack = _mk_stack(n_shards=1, pages_per_shard=2, page_size=16)
    meta = RNG.integers(100, 200, 4)
    payload = RNG.integers(1000, 2000, 100)   # 7 pages > 2 -> exhaustion
    sock = stack.socket("length-prefixed")
    sock.deliver(build_message(meta, payload))
    while sock.rx_available() > 0:
        _, n = sock.recv(1 << 20)
        if n == 0:
            break
    c = stack.counters
    assert c.meta_copied == 3 + 4          # header + meta, exactly once
    assert c.full_copied == 100            # payload portion, exactly once
    assert c.total_user_copies() == 3 + 4 + 100
    assert c.anchored == 0 and c.zero_copied == 0


def test_partial_payload_delivery_waits_then_anchors():
    """Regression: the selective path must not anchor until the whole
    declared payload is resident (DMA-complete precondition) — anchoring a
    half-delivered message used to write zeros into the pool and push the
    read offset past the queue."""
    stack = _mk_stack()
    meta = RNG.integers(100, 200, 4)
    payload = RNG.integers(1000, 2000, 32)
    msg = build_message(meta, payload)
    src, dst = stack.socket_pair("length-prefixed")
    src.deliver(msg[: 3 + 4 + 10])          # header + meta + 10 of 32 payload
    buf, n = src.recv(1 << 20)
    assert n == 0 and len(buf) == 0         # waits; nothing consumed
    assert src.rx_available() == 3 + 4 + 10
    src.deliver(msg[3 + 4 + 10 :])          # the rest arrives
    buf, n = src.recv(1 << 20)
    assert n == 3 + 4 + 32
    src.forward(dst, buf)
    assert np.array_equal(dst.tx_wire()[-32:], payload)  # no zeros anchored
    assert len(stack.registry) == 0


def test_partial_delivery_under_exhaustion_never_overshoots():
    """Companion clamp: even on the pool-exhaustion fallback, recv must
    never advance past the delivered bytes."""
    stack = _mk_stack(n_shards=1, pages_per_shard=2, page_size=16)
    meta = RNG.integers(100, 200, 2)
    payload = RNG.integers(1000, 2000, 200)  # 13 pages > 2 -> exhaustion
    msg = build_message(meta, payload)
    sock = stack.socket("length-prefixed")
    sock.deliver(msg[:40])
    buf, n = sock.recv(1 << 20)
    assert n == 0                            # incomplete: waits
    sock.deliver(msg[40:])
    parts, total = [], 0
    while sock.rx_available() > 0:
        buf, n = sock.recv(1 << 20)
        if n == 0:
            break
        parts.append(buf)
        total += n
    got = np.concatenate(parts)
    assert np.array_equal(got[-200:], payload)
    assert sock.rx_available() == 0


# ---------------------------------------------------------------------------
# close + tick-driven deferred teardown
# ---------------------------------------------------------------------------

def test_close_defers_then_tick_reclaims():
    stack = _mk_stack(grace_ticks=3)
    msg, _, payload = _msg(payload_n=64)   # 4 pages at page_size=16
    sock = stack.socket("length-prefixed")
    sock.deliver(msg)
    sock.recv(1 << 20)
    assert stack.pages_in_use == 4
    deferred = sock.close()
    assert deferred == 1 and sock.closed
    assert sock.fileno() not in stack.sockets
    # §A.4: pages survive the grace period, then tick() reclaims them
    assert stack.tick(2) == 0
    assert stack.pages_in_use == 4
    assert stack.tick(2) == 4
    assert stack.alloc.free_pages == stack.alloc.total_pages
    assert len(stack.registry) == 0


def test_close_idempotent_and_recv_raises():
    stack = _mk_stack()
    sock = stack.socket("length-prefixed")
    assert sock.close() == 0
    assert sock.close() == 0
    with pytest.raises(OSError):
        sock.recv(16)
    with pytest.raises(OSError):
        sock.send(np.zeros(4, np.int64))


# ---------------------------------------------------------------------------
# poll / readiness
# ---------------------------------------------------------------------------

def test_poll_events():
    stack = _mk_stack()
    msg, _, _ = _msg()
    src, dst = stack.socket_pair("length-prefixed", send_budget=8)
    assert src.poll() == Events.WRITABLE
    src.deliver(msg)
    assert src.poll() & Events.READABLE
    buf, _ = src.recv(1 << 20)
    src.forward(dst, buf)          # budget-truncated
    assert dst.poll() & Events.SEND_PENDING
    while dst.pending_send is not None:
        dst.send()
    assert not dst.poll() & Events.SEND_PENDING
    dst.close()
    assert dst.poll() == Events.CLOSED
    stack.drain()
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_stack_poll_snapshot():
    stack = _mk_stack()
    a = stack.socket("length-prefixed")
    b = stack.socket("delimiter")
    a.deliver(np.arange(8))
    snap = stack.poll()
    assert snap[a.fileno()] & Events.READABLE
    assert not snap[b.fileno()] & Events.READABLE
