"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
asserting allclose against the pure-jnp oracles in kernels/ref.py
(interpret=True executes the TPU kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import ops
from repro.kernels import ref as R

RNG = np.random.default_rng(7)


def randn(shape, dtype=jnp.float32):
    return jnp.array(RNG.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,hd,blk", [
    (1, 1, 1, 128, 64, 64),
    (2, 4, 2, 256, 64, 128),
    (1, 8, 8, 128, 128, 64),   # MHA
    (2, 6, 2, 128, 32, 64),    # GQA group 3
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_shapes(b, hq, hkv, s, hd, blk, dtype):
    q, k, v = (randn((b, hq, s, hd), dtype), randn((b, hkv, s, hd), dtype),
               randn((b, hkv, s, hd), dtype))
    out = ops.flash_attention(q, k, v, causal=True, impl="interpret",
                              block_q=blk, block_k=blk)
    want = R.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_window(window):
    q, k, v = randn((1, 2, 256, 32)), randn((1, 2, 256, 32)), randn((1, 2, 256, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              impl="interpret", block_q=64, block_k=64)
    want = R.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.array(out), np.array(want), atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    q, k, v = randn((2, 2, 128, 32)), randn((2, 2, 128, 32)), randn((2, 2, 128, 32))
    out = ops.flash_attention(q, k, v, causal=False, impl="interpret",
                              block_q=64, block_k=64)
    want = R.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.array(out), np.array(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

def _paged_setup(b, hq, hkv, hd, page, pps, p_total, seq_lens):
    q = randn((b, hq, hd))
    pool = randn((p_total, page, 2, hkv, hd))
    tables = np.full((b, pps), -1, np.int32)
    page_pos = np.full((b, pps), -(2 ** 20), np.int32)
    ctr = 0
    for i in range(b):
        for j in range(seq_lens[i] // page + 1):
            tables[i, j] = ctr % p_total
            page_pos[i, j] = j * page
            ctr += 1
    return q, pool, jnp.array(tables), jnp.array(page_pos), jnp.array(seq_lens, jnp.int32)


@pytest.mark.parametrize("b,hq,hkv,hd,page", [
    (2, 4, 2, 64, 16), (3, 8, 8, 32, 8), (1, 6, 1, 128, 32),
])
def test_paged_matches_ref(b, hq, hkv, hd, page):
    seq = RNG.integers(1, page * 3, b)
    q, pool, tbl, pp, sl = _paged_setup(b, hq, hkv, hd, page, 4, 24, seq)
    got = ops.paged_attention(q, pool, tbl, pp, sl, impl="interpret")
    want = R.paged_attention_ref(q, pool, tbl, pp, sl)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.array(g), np.array(w), atol=1e-4, rtol=1e-4)


def test_paged_equals_dense_attention():
    """Combined partials must equal full attention over the logical KV."""
    b, hq, hkv, hd, page = 2, 4, 2, 32, 8
    seq = np.array([20, 13])
    q, pool, tbl, pp, sl = _paged_setup(b, hq, hkv, hd, page, 6, 32, seq)
    acc, m, l = ops.paged_attention(q, pool, tbl, pp, sl, impl="interpret")
    out = np.array(acc / np.maximum(np.array(l), 1e-30)[..., None])
    # dense reference: rebuild contiguous KV from pages
    for i in range(b):
        ln = seq[i] + 1
        kk = np.zeros((ln, hkv, hd), np.float32)
        vv = np.zeros((ln, hkv, hd), np.float32)
        for j in range(ln // page + 1):
            pid = int(tbl[i, j])
            if pid < 0:
                continue
            lo = j * page
            hi = min(lo + page, ln)
            kk[lo:hi] = np.array(pool[pid, : hi - lo, 0])
            vv[lo:hi] = np.array(pool[pid, : hi - lo, 1])
        qg = np.array(q[i]).reshape(hkv, hq // hkv, hd) / np.sqrt(hd)
        s = np.einsum("hgd,thd->hgt", qg, kk)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        o = np.einsum("hgt,thd->hgd", p, vv).reshape(hq, hd)
        np.testing.assert_allclose(out[i], o, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# selective copy
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.data())
def test_selective_copy_property(data):
    """Metadata lands in the compact buffer; payload lands in its pages;
    untouched pages are preserved — for arbitrary parse boundaries."""
    b = data.draw(st.integers(1, 3))
    page = data.draw(st.sampled_from([8, 16]))
    pps = 4
    s = 16 + pps * page
    p_total = b * pps + 2
    meta_max = 16
    stream = jnp.array(RNG.integers(1, 1000, (b, s)), jnp.int32)
    meta_len, total_len, tables = [], [], np.full((b, pps), -1, np.int32)
    ctr = 0
    for i in range(b):
        ml = data.draw(st.integers(0, meta_max))
        pl_len = data.draw(st.integers(0, pps * page))
        meta_len.append(ml)
        total_len.append(ml + pl_len)
        for j in range(-(-pl_len // page)):
            tables[i, j] = ctr
            ctr += 1
    meta_len = jnp.array(meta_len, jnp.int32)
    total_len = jnp.array(total_len, jnp.int32)
    pool = jnp.array(RNG.integers(0, 5, (p_total, page)), jnp.int32)
    got_m, got_p = ops.selective_copy(stream, meta_len, total_len, pool,
                                      jnp.array(tables), meta_max=meta_max,
                                      impl="interpret")
    want_m, want_p = R.selective_copy_ref(stream, meta_len, total_len, pool,
                                          jnp.array(tables), meta_max=meta_max)
    assert np.array_equal(np.array(got_m), np.array(want_m))
    assert np.array_equal(np.array(got_p), np.array(want_p))
    # semantic checks against the raw stream
    for i in range(b):
        ml, tl = int(meta_len[i]), int(total_len[i])
        assert np.array_equal(np.array(got_m[i, :ml]), np.array(stream[i, :ml]))
        for j, pid in enumerate(tables[i]):
            if pid < 0:
                continue
            lo, hi = ml + j * page, min(ml + (j + 1) * page, tl)
            if hi > lo:
                assert np.array_equal(np.array(got_p[pid, : hi - lo]),
                                      np.array(stream[i, lo:hi]))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_selective_copy_reserved_scratch_bitexact(seed):
    """The fused kernel running over the pool's reserved scratch row (the
    zero-realloc hot path) stays bit-exact with the oracle — including the
    scratch row itself, which must come back untouched."""
    from repro.kernels.selective_copy import selective_copy
    from repro.kernels.testing import selcopy_case

    stream, ml, tl, pool, tables = selcopy_case(np.random.default_rng(seed))
    got_m, got_p = selective_copy(stream, ml, tl, pool, tables, meta_max=16,
                                  interpret=True, reserved_scratch=True)
    want_m, want_p = R.selective_copy_ref(stream, ml, tl, pool, tables,
                                          meta_max=16)
    assert got_p.shape == pool.shape         # scratch row kept in place
    assert np.array_equal(np.array(got_m), np.array(want_m))
    assert np.array_equal(np.array(got_p), np.array(want_p))


def test_selective_copy_hot_path_has_no_pool_copy():
    """Regression for the fused zero-realloc datapath: with the reserved
    scratch row the trace must contain exactly ONE pallas_call (meta +
    payload fused) and no concatenate/pad (the old implementation extended
    the pool by a dummy row — an O(pool) copy — on every invocation)."""
    import functools

    from repro.kernels.selective_copy import selective_copy
    from repro.kernels.testing import (
        POOL_COPY_PRIMS,
        jaxpr_primitives,
        selcopy_case,
    )

    stream, ml, tl, pool, tables = selcopy_case(np.random.default_rng(0))
    fn = functools.partial(selective_copy, meta_max=16, interpret=True,
                           reserved_scratch=True)
    names = jaxpr_primitives(jax.make_jaxpr(fn)(stream, ml, tl, pool,
                                                tables).jaxpr)
    assert names.count("pallas_call") == 1     # single fused dispatch
    assert not set(names) & set(POOL_COPY_PRIMS)
    # the legacy (scratch-less) path still shows its copy — keeps this
    # test honest about what it detects
    legacy = functools.partial(selective_copy, meta_max=16, interpret=True,
                               reserved_scratch=False)
    lnames = jaxpr_primitives(jax.make_jaxpr(legacy)(stream, ml, tl,
                                                     pool[:-1], tables).jaxpr)
    assert "concatenate" in lnames


# ---------------------------------------------------------------------------
# selective gather (egress mirror)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,page,pps", [(1, 8, 2), (2, 8, 4), (3, 16, 3),
                                        (4, 8, 1)])
@pytest.mark.parametrize("with_ks", [False, True])
def test_selective_gather_matches_ref(b, page, pps, with_ks):
    """The fused egress gather (interpret mode) is bit-exact with its
    oracle, with and without the hw-kTLS TX keystream operand."""
    from repro.kernels.selective_copy import selective_gather
    from repro.kernels.testing import selgather_case

    pool, tables, lengths, ks = selgather_case(
        np.random.default_rng(11 * b + pps), b=b, page=page, pps=pps)
    k = ks if with_ks else None
    got = selective_gather(pool, tables, lengths, interpret=True, keystream=k)
    want = R.selective_gather_ref(pool, tables, lengths, k)
    assert np.array_equal(np.array(got), np.array(want))
    # semantic check: each valid page slot j carries payload span
    # [j*page, (j+1)*page) of its source page, XORed with the keystream
    host = np.array(got)
    for i in range(b):
        ln = int(lengths[i])
        assert not host[i, ln:].any()            # zero past the length
        for j, pid in enumerate(np.array(tables[i])):
            lo, hi = j * page, min((j + 1) * page, ln)
            if pid < 0 or hi <= lo:
                continue
            want_span = np.array(pool[pid, : hi - lo])
            if with_ks:
                want_span = np.bitwise_xor(want_span,
                                           np.array(ks[i, lo:hi]))
            assert np.array_equal(host[i, lo:hi], want_span)


def test_selective_gather_reads_pool_in_place():
    """The gather's jaxpr must contain one fused dispatch and no
    pool-sized copy (no concatenate/pad): the resident pool is read
    where it lives."""
    import functools

    from repro.kernels.selective_copy import selective_gather
    from repro.kernels.testing import (
        POOL_COPY_PRIMS,
        jaxpr_primitives,
        selgather_case,
    )

    pool, tables, lengths, ks = selgather_case(np.random.default_rng(0))
    for k in (None, ks):
        fn = functools.partial(selective_gather, interpret=True, keystream=k)
        names = jaxpr_primitives(jax.make_jaxpr(fn)(pool, tables,
                                                    lengths).jaxpr)
        assert names.count("pallas_call") == 1
        assert not set(names) & set(POOL_COPY_PRIMS)


def test_selective_gather_ops_dispatch():
    from repro.kernels.testing import selgather_case

    pool, tables, lengths, ks = selgather_case(np.random.default_rng(5))
    want = R.selective_gather_ref(pool, tables, lengths, ks)
    for impl in ("ref", "interpret"):
        got = ops.selective_gather(pool, tables, lengths, impl=impl,
                                   keystream=ks)
        assert np.array_equal(np.array(got), np.array(want)), impl


# ---------------------------------------------------------------------------
# mlstm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,dh,chunk", [
    (1, 1, 64, 32, 16), (2, 3, 64, 32, 32), (1, 2, 128, 64, 16),
])
def test_mlstm_matches_sequential(b, h, s, dh, chunk):
    q, k, v = randn((b, h, s, dh)), randn((b, h, s, dh)), randn((b, h, s, dh))
    li = randn((b, h, s))
    lf = jnp.array(np.log(1 / (1 + np.exp(-(RNG.standard_normal((b, h, s)) + 2)))),
                   jnp.float32)
    got = ops.mlstm_scan(q, k, v, li, lf, chunk=chunk, impl="interpret")
    want = R.mlstm_scan_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=5e-4,
                               rtol=5e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([16, 32]))
def test_mlstm_gate_extremes(b, chunk):
    """Strong forget gates (decay ~0) and strong inputs stay stable."""
    h, s, dh = 2, 64, 16
    q, k, v = randn((b, h, s, dh)), randn((b, h, s, dh)), randn((b, h, s, dh))
    li = jnp.array(RNG.standard_normal((b, h, s)) * 4, jnp.float32)
    lf = jnp.array(np.log(1 / (1 + np.exp(-(RNG.standard_normal((b, h, s)) * 4)))),
                   jnp.float32)
    got = ops.mlstm_scan(q, k, v, li, lf, chunk=chunk, impl="interpret")
    want = R.mlstm_scan_ref(q, k, v, li, lf)
    assert np.all(np.isfinite(np.array(got)))
    np.testing.assert_allclose(np.array(got), np.array(want), atol=3e-3,
                               rtol=3e-3)
