"""Batched zero-realloc datapath: ring-buffer RX queues, fused batch
scatter/gather, stack-wide recv_batch/forward_batch, batched ProxyRuntime
rounds (scalar-parity, exhaustion/teardown interleaving), and pool
backpressure."""
import numpy as np
import pytest

from repro.core import (
    AnchorPool,
    LibraStack,
    ProxyRuntime,
    build_chunked_message,
    build_delimited_message,
    build_message,
)
from repro.core.runtime import LatencyHistogram
from repro.core.stream import RxRing, TokenPool

RNG = np.random.default_rng(23)

BUILDERS = {
    "length-prefixed": build_message,
    "delimiter": build_delimited_message,
    "chunked": lambda m, p: build_chunked_message(
        [p[i : i + 24] for i in range(0, len(p), 24)]),
}


def _stack(**kw):
    kw.setdefault("n_shards", 4)
    kw.setdefault("pages_per_shard", 128)
    kw.setdefault("page_size", 16)
    kw.setdefault("secret", b"bd")
    return LibraStack(**kw)


def _load(stack, rt, *, n_chans=6, n_msgs=4, payload=72, meta=6, seed=5,
          protos=("length-prefixed", "delimiter", "chunked"), **chan_kw):
    dsts = []
    rng = np.random.default_rng(seed)
    for i in range(n_chans):
        proto = protos[i % len(protos)]
        src, dst = stack.socket_pair(proto)
        rt.channel(src, dst, name=f"{proto}-{i}", **chan_kw)
        dsts.append(dst)
        for _ in range(n_msgs):
            src.deliver(BUILDERS[proto](rng.integers(100, 200, meta),
                                        rng.integers(1000, 2000, payload)))
    return dsts


# ---------------------------------------------------------------------------
# RxRing
# ---------------------------------------------------------------------------

def test_rx_ring_fifo_and_zero_copy_views():
    r = RxRing(capacity=16)
    r.push(np.arange(10))
    assert len(r) == 10
    v = r.peek(4)
    assert v.base is not None           # a view, not a copy
    assert np.array_equal(v, [0, 1, 2, 3])
    r.advance(4)
    assert np.array_equal(r.peek(100), np.arange(4, 10))
    r.push(np.arange(100, 140))         # forces growth, preserves order
    assert np.array_equal(r.peek(1000),
                          np.concatenate([np.arange(4, 10), np.arange(100, 140)]))
    assert r.fingerprint() == (4, 50)


def test_rx_ring_small_queue_does_not_retain_dead_prefix():
    """Regression for the hardcoded 65536 compaction threshold: a workload
    of small messages must keep the buffer bounded instead of retaining an
    ever-growing dead prefix."""
    r = RxRing(capacity=16, min_compact=8)
    for i in range(2000):
        r.push(np.full(8, i))
        r.advance(8)
    assert len(r) == 0
    assert r.capacity <= 64             # stayed small: dead prefix reclaimed
    assert r.fingerprint() == (16000, 16000)


def test_rx_ring_capacity_tracks_live_region_not_history():
    r = RxRing(capacity=16)
    for i in range(100):
        r.push(RNG.integers(0, 9, 32))
        r.advance(32)
    assert r.capacity <= 256
    # live data still correct after many slides
    r.push(np.arange(7))
    assert np.array_equal(r.peek(7), np.arange(7))


# ---------------------------------------------------------------------------
# TokenPool vectorized + batched scatter/gather
# ---------------------------------------------------------------------------

def _loop_write(pool, pages, payload):
    """The original per-page loop semantics (oracle for the vector path)."""
    ps = pool.alloc.page_size
    for pg in pages:
        lo = pg.base_pos
        hi = min(lo + ps, len(payload))
        if lo >= len(payload):
            break
        pool.data[pg.shard, pg.local_pid, : hi - lo] = payload[lo:hi]


def test_tokenpool_vectorized_matches_loop_semantics():
    alloc = AnchorPool(2, 8, 8)
    a, b = TokenPool(alloc), TokenPool(alloc)
    for ln in (1, 7, 8, 9, 20, 24):
        pages = alloc.alloc_sequence(ln)
        payload = RNG.integers(0, 1000, ln)
        a.write_payload(pages, payload)
        _loop_write(b, pages, payload)
        assert np.array_equal(a.data, b.data), ln
        assert np.array_equal(a.read_payload(pages, ln), payload)
        alloc.free_pages_list(pages)


def test_tokenpool_batched_roundtrip_matches_scalar():
    alloc = AnchorPool(4, 64, 16)
    pool = TokenPool(alloc)
    seqs, payloads = [], []
    for ln in (5, 16, 33, 100, 1, 64):
        pages = alloc.alloc_sequence(ln)
        payloads.append(RNG.integers(0, 1000, ln))
        seqs.append((pages, payloads[-1]))
    pool.write_payload_batch(seqs)
    # batched write == per-message writes
    pool2 = TokenPool(alloc)
    for (pages, p) in seqs:
        pool2.write_payload(pages, p)
    assert np.array_equal(pool.data, pool2.data)
    # batched read == per-message reads
    got = pool.read_payload_batch([(pg, len(p)) for pg, p in seqs])
    for g, p in zip(got, payloads):
        assert np.array_equal(g, p)


def test_tokenpool_batched_tiles_large_batches():
    alloc = AnchorPool(4, 256, 8)
    pool = TokenPool(alloc)
    # adaptive tiling: shrink the cache budget so this batch is forced to
    # span several tiles (1 page × 8 tokens × 16 B = 128 B per message)
    pool.cache_budget = 128 * 16
    seqs = []
    for i in range(50):
        pages = alloc.alloc_sequence(3)
        seqs.append((pages, np.full(3, i)))
    assert pool.batch_tile([(pg, 3) for pg, _ in seqs]) == 16
    pool.write_payload_batch(seqs)
    got = pool.read_payload_batch([(pg, 3) for pg, _ in seqs])
    for i, g in enumerate(got):
        assert np.array_equal(g, np.full(3, i))


def test_tokenpool_adaptive_tile_tracks_footprint():
    """The tile adapts to live footprint: page-heavy messages get small
    tiles, tiny ones fuse broadly — pages × page_size vs cache_budget."""
    alloc = AnchorPool(4, 256, 16)
    pool = TokenPool(alloc)
    big = [(alloc.alloc_sequence(16 * 16), 16 * 16) for _ in range(4)]
    small = [(alloc.alloc_sequence(8), 8) for _ in range(4)]
    t_big, t_small = pool.batch_tile(big), pool.batch_tile(small)
    assert t_big < t_small
    assert t_big == pool.cache_budget // (16 * 16 * 16)
    assert 1 <= t_big and t_small <= 4096
    for pages, _ in big + small:
        alloc.free_pages_list(pages)


def test_tokenpool_reserves_scratch_row():
    alloc = AnchorPool(2, 4, 8)
    pool = TokenPool(alloc)
    assert alloc.scratch_page == 8
    assert pool.flat_with_scratch.shape == (9, 8)
    # real-page writes land in the flat view; scratch row is extra
    pages = alloc.alloc_sequence(8)
    pool.write_payload(pages, np.arange(8))
    flat = pool.flat_with_scratch
    assert np.array_equal(flat[alloc.flat_pid(pages[0])], np.arange(8))


# ---------------------------------------------------------------------------
# recv_batch / forward_batch parity with the scalar facade
# ---------------------------------------------------------------------------

def test_recv_batch_matches_scalar_recv():
    def load(stack):
        socks = []
        rng = np.random.default_rng(1)
        for proto in ("length-prefixed", "delimiter", "length-prefixed"):
            s = stack.socket(proto)
            s.deliver(BUILDERS[proto](rng.integers(100, 200, 5),
                                      rng.integers(1000, 2000, 40)))
            socks.append(s)
        return socks

    sa, sb = _stack(), _stack()
    socks_a, socks_b = load(sa), load(sb)
    scalar = {s.fileno(): s.recv(1 << 20) for s in socks_a}
    batched = sb.recv_batch(socks_b, 1 << 20)
    assert set(batched) == {s.fileno() for s in socks_b}
    for s_a, s_b in zip(socks_a, socks_b):
        buf_a, n_a = scalar[s_a.fileno()]
        buf_b, n_b = batched[s_b.fileno()]
        assert n_a == n_b
        # metadata identical; VPI token differs only by registry order
        assert np.array_equal(buf_a[:-1], buf_b[:-1])
    assert sa.counters.snapshot() == sb.counters.snapshot()
    assert np.array_equal(sa.pool.data, sb.pool.data)


def test_recv_batch_skips_inadmissible_sockets():
    stack = _stack()
    ok = stack.socket("length-prefixed")
    ok.deliver(build_message(np.arange(4), RNG.integers(0, 9, 32)))
    short = stack.socket("length-prefixed")       # payload under threshold
    short.deliver(build_message(np.arange(4), RNG.integers(0, 9, 4)))
    partial = stack.socket("length-prefixed")     # DMA incomplete
    partial.deliver(build_message(np.arange(4), RNG.integers(0, 9, 64))[:20])
    raw = stack.socket("length-prefixed")         # unparseable
    raw.deliver(np.array([99, 98, 97, 96]))
    idle = stack.socket("length-prefixed")        # nothing buffered
    tiny = stack.socket("length-prefixed")        # no room for meta+VPI
    tiny.deliver(build_message(np.arange(4), RNG.integers(0, 9, 32)))

    res = stack.recv_batch([ok, short, partial, raw, idle],
                           {tiny.fileno(): 3})
    res.update(stack.recv_batch([tiny], {tiny.fileno(): 3}))
    assert set(res) == {ok.fileno()}
    # the skipped sockets still work through scalar recv (fallback path)
    buf, n = short.recv(1 << 20)
    assert n == 3 + 4 + 4                  # full copy, admission threshold
    assert stack.counters.full_copied == n


def test_recv_batch_kernel_impls_match_host():
    for impl in ("ref", "interpret"):
        sh, sk = _stack(), _stack()
        for stack in (sh, sk):
            rng = np.random.default_rng(9)
            socks = [stack.socket("length-prefixed") for _ in range(4)]
            for s in socks:
                s.deliver(build_message(rng.integers(100, 200, 7),
                                        rng.integers(1000, 2000, 50)))
            stack.recv_batch(socks, impl=("host" if stack is sh else impl))
        assert np.array_equal(sh.pool.data, sk.pool.data), impl
        assert sh.counters.snapshot() == sk.counters.snapshot()


def test_recv_batch_device_impl_preserves_int64_pool_rows():
    """Regression: the device impls ride an int32 stream, but their pool
    write-back must touch ONLY the rows the batch anchored — payloads with
    >=2^31 tokens anchored earlier by the int64-exact paths survive."""
    stack = _stack()
    big = stack.socket("length-prefixed")
    huge = np.array([2 ** 40 + 5, -(2 ** 35), 2 ** 31, 7] * 4, np.int64)
    big.deliver(build_message(np.arange(3), huge))
    big.recv(1 << 20)                       # int64-exact scalar anchoring
    (pages, ln), = big.connection.anchored.values()
    others = [stack.socket("length-prefixed") for _ in range(3)]
    for s in others:
        s.deliver(build_message(np.arange(4), RNG.integers(0, 9, 48)))
    res = stack.recv_batch(others, impl="ref")
    assert len(res) == 3
    assert np.array_equal(stack.pool.read_payload(pages, ln), huge)


def test_runtime_batched_matches_scalar_end_to_end():
    def run(batched, **kw):
        stack = _stack()
        rt = ProxyRuntime(stack, tick_every=8, batched=batched)
        dsts = _load(stack, rt, **kw)
        rt.run()
        wires = [d.tx_wire() for d in dsts]
        msgs = rt.messages_forwarded()
        rt.shutdown()
        assert stack.alloc.free_pages == stack.alloc.total_pages
        return stack.counters.snapshot(), wires, msgs

    # recv_buf values 12/30 sit INSIDE [meta_len+1, meta_len+payload_len)
    # for some protocols — the truncated-buffer regression range: the batch
    # must hand such sockets to scalar recv (which owns capped logical
    # delivery) and stay byte/counter-identical end to end
    for kw in ({}, {"budget": 20}, {"recv_buf": 4}, {"recv_buf": 12},
               {"recv_buf": 30, "budget": 16}):
        cs, ws, ms = run(False, **kw)
        cb, wb, mb = run(True, **kw)
        assert cs == cb, kw
        assert ms == mb, kw
        for a, b in zip(ws, wb):
            assert np.array_equal(a, b), kw


def _shared_dst_scenario(batched, *, budgets):
    """Two messages routed to ONE backend socket in a single round."""
    stack = _stack()
    shared = stack.socket("length-prefixed")
    srcs = [stack.socket("length-prefixed") for _ in range(2)]
    bufs = []
    rng = np.random.default_rng(3)
    for s in srcs:
        s.deliver(build_message(np.arange(3), rng.integers(1000, 2000, 40)))
        bufs.append(s.recv(1 << 20)[0])
    sends = list(zip(srcs, [shared, shared], bufs, budgets))
    if batched:
        out = stack.forward_batch(sends)
    else:
        out = []
        for s, d, b, bud in sends:
            try:
                out.append(("ok", s.forward(d, b, budget=bud)))
            except BlockingIOError:
                out.append(("eagain", 0))
    return out, stack.counters.snapshot(), shared.pending_send is not None


def test_forward_batch_shared_destination_matches_scalar():
    """Regression (stale-peek bug): two sends in one round targeting the
    same destination must produce exactly the scalar outcomes + counters —
    EAGAIN when the first send truncates, sequential completion when it
    does not."""
    for budgets in ((20, 20), (None, 20), (None, None)):
        s_out, s_snap, s_pend = _shared_dst_scenario(False, budgets=budgets)
        b_out, b_snap, b_pend = _shared_dst_scenario(True, budgets=budgets)
        assert s_out == b_out, budgets
        assert s_snap == b_snap, budgets
        assert s_pend == b_pend, budgets


def test_forward_batch_multicast_release_matches_scalar():
    """Regression: the same VPI forwarded to TWO destinations in one round.
    The first transmit releases the entry; the second's peek is stale — it
    must be re-evaluated at transmit time (scalar semantics: the dead VPI
    rides the bypass path) instead of mis-sizing the pending message and
    wedging the socket forever."""
    def run(batched):
        stack = _stack()
        d1, d2 = stack.socket("length-prefixed"), stack.socket("length-prefixed")
        src = stack.socket("length-prefixed")
        src.deliver(build_message(np.arange(3), RNG.integers(1000, 2000, 40)))
        buf, _ = src.recv(1 << 20)
        if batched:
            out = stack.forward_batch([(src, d1, buf, None),
                                       (src, d2, buf, None)])
        else:
            out = [("ok", src.forward(d, buf)) for d in (d1, d2)]
        return (out, stack.counters.snapshot(),
                d1.pending_send is not None, d2.pending_send is not None)

    scalar, batched = run(False), run(True)
    assert scalar == batched
    assert batched[3] is False     # the wedge: d2 stuck pending forever


def test_recv_batch_inconsistent_machine_frees_pages():
    """Regression: a machine that does not land in WRITE_VPI (impossible
    unless a parser violates purity, but a bare assert used to leak the
    freshly allocated pages) must hand the pages back and leave the socket
    to the scalar path."""
    from repro.core.state_machine import RxDecision, St

    stack = _stack()
    sock = stack.socket("length-prefixed")
    sock.deliver(build_message(np.arange(4), RNG.integers(1000, 2000, 40)))
    free_before = stack.alloc.free_pages
    sm = sock.connection.rx_machine
    orig = sm.on_recv
    sm.on_recv = lambda *a, **k: RxDecision(St.METADATA_PARSED, copy_meta=0)
    res = stack.recv_batch([sock])
    assert res == {}                                   # not serviced
    assert stack.alloc.free_pages == free_before       # nothing leaked
    assert sm.state is St.DEFAULT                      # reset, ring untouched
    sm.on_recv = orig
    buf, n = sock.recv(1 << 20)                        # scalar path recovers
    assert n == 3 + 4 + 40


def test_recv_batch_device_overflow_falls_back_to_host():
    """Regression: int64 tokens that do not fit the int32 device stream
    used to truncate silently in the kernel impls — the round must bounce
    to the int64-exact host scatter and count the event."""
    stack = _stack()
    big = stack.socket("length-prefixed")
    huge = np.array([2 ** 40 + 5, -(2 ** 35), 2 ** 31, 7] * 4, np.int64)
    big.deliver(build_message(np.arange(3), huge))
    small = stack.socket("length-prefixed")
    small.deliver(build_message(np.arange(4), RNG.integers(0, 9, 48)))
    res = stack.recv_batch([big, small], impl="ref")
    assert len(res) == 2                               # both serviced
    assert stack.counters.device_fallbacks == 1
    (pages, ln), = big.connection.anchored.values()
    assert np.array_equal(stack.pool.read_payload(pages, ln), huge)
    # an in-range round afterwards still uses the device plane (no sticky
    # fallback) and the counter does not move
    ok = stack.socket("length-prefixed")
    ok.deliver(build_message(np.arange(4), RNG.integers(0, 9, 32)))
    stack.recv_batch([ok], impl="ref")
    assert stack.counters.device_fallbacks == 1


def test_abort_transfer_restores_budget():
    """§A.2/§A.3 regression: a transfer staged but never committed used to
    leave the send-side budget raised forever; the egress failure path now
    aborts it."""
    alloc = AnchorPool(2, 8, 8)
    pages = alloc.alloc_sequence(20)
    staged = alloc.stage_transfer(pages)
    assert alloc._budget_raise == len(staged)
    alloc.abort_transfer(staged)
    assert alloc._budget_raise == 0

    # end to end: a payload compose that raises mid-handoff aborts the
    # staging, and the same message transmits cleanly on retry
    stack = _stack()
    src, dst = stack.socket_pair("length-prefixed")
    payload = RNG.integers(1000, 2000, 40)
    src.deliver(build_message(np.arange(3), payload))
    buf, _ = src.recv(1 << 20)
    orig = stack.pool.read_payload
    stack.pool.read_payload = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("compose failed"))
    with pytest.raises(RuntimeError):
        src.forward(dst, buf)
    stack.pool.read_payload = orig
    assert stack.alloc._budget_raise == 0              # aborted, not leaked
    dst.connection.tx_machine.reset()                  # abandon the half-send
    dst._pending = None
    n = src.forward(dst, buf)
    assert n == 3 + 3 + 40
    assert np.array_equal(dst.tx_wire()[-40:], payload)


def test_forward_batch_eagain_on_shared_backend():
    stack = _stack()
    shared = stack.socket("length-prefixed")
    srcs = [stack.socket("length-prefixed") for _ in range(2)]
    sends = []
    for s in srcs:
        s.deliver(build_message(np.arange(3), RNG.integers(1000, 2000, 40)))
        buf, n = s.recv(1 << 20)
        sends.append((s, shared, buf, 10))      # budget-truncated
    out = stack.forward_batch(sends)
    assert out[0][0] == "ok" and out[0][1] == 10
    assert out[1] == ("eagain", 0)              # backend busy: held, like scalar
    while shared.pending_send is not None:
        shared.send(budget=10)
    # retried send now goes through
    out2 = stack.forward_batch([sends[1]])
    assert out2[0][0] == "ok"


# ---------------------------------------------------------------------------
# exhaustion / teardown interleaved with batched rounds
# ---------------------------------------------------------------------------

def test_batched_pool_exhaustion_falls_back_scalar_drain():
    """A pool too small for a whole batched round: overflow is handed to
    the scalar §A.1 drain machinery without corrupting counters or wedging
    the RX machines — every payload still arrives byte-identical. (Anchoring
    a round at a time raises peak pool pressure, so WHICH path a message
    takes legitimately differs from the scalar schedule; the wire bytes and
    message counts must not.)"""
    def run(batched):
        stack = _stack(n_shards=1, pages_per_shard=6, page_size=16)
        rt = ProxyRuntime(stack, tick_every=4, batched=batched)
        # backpressure off: force the overflow path on purpose
        dsts = _load(stack, rt, n_chans=4, n_msgs=3, payload=64,
                     protos=("length-prefixed",), backpressure=False)
        rt.run()
        wires = [d.tx_wire() for d in dsts]
        msgs = rt.messages_forwarded()
        counters = stack.counters
        # self-consistency: anchored tokens all left zero-copy, and every
        # logical byte went down exactly one path
        assert counters.anchored == counters.zero_copied
        rt.shutdown()
        assert stack.alloc.free_pages == stack.alloc.total_pages
        return counters, wires, msgs

    cs, ws, ms = run(False)
    cb, wb, mb = run(True)
    assert ms == mb == 12
    assert cb.full_copied > 0          # drain mode engaged in the batch run
    assert cb.vpi_injected > 0         # ...while other messages stayed fast
    for a, b in zip(ws, wb):
        assert np.array_equal(a, b)    # byte-identical delivery regardless


def test_batched_rounds_interleave_with_deferred_teardown():
    """Closing an anchoring socket mid-run (§A.4) while batched rounds keep
    flowing: the grace period expires via the runtime tick, nothing wedges,
    and the pool fully drains."""
    stack = _stack(grace_ticks=2)
    rt = ProxyRuntime(stack, tick_every=1, batched=True)
    dsts = _load(stack, rt, n_chans=3, n_msgs=2,
                 protos=("length-prefixed",))
    # one extra socket anchors a message, then dies with it in flight
    dying = stack.socket("length-prefixed")
    dying.deliver(build_message(np.arange(3), RNG.integers(0, 9, 64)))
    dying.recv(1 << 20)
    dying.close()
    assert stack.pages_in_use > 0
    rt.run()
    for _ in range(4):
        rt.step()                      # idle ticks expire the grace period
    assert rt.messages_forwarded() == 6
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages
    assert len(stack.registry) == 0


def test_batched_teardown_mid_truncated_send_still_drains():
    """Scalar regression scenario, batched mode: client closes while its
    message is budget-truncated on a shared backend; the frame finishes and
    later batched traffic flows."""
    stack = _stack(grace_ticks=3)
    rt = ProxyRuntime(stack, tick_every=1, batched=True)
    shared = stack.socket("length-prefixed")
    pa = RNG.integers(1000, 2000, 40)
    pb = RNG.integers(3000, 4000, 40)
    a = stack.socket("length-prefixed")
    rt.channel(a, shared, budget=16)
    a.deliver(build_message(np.arange(3), pa))
    rt.step()
    assert shared.pending_send is not None
    a.close()
    b = stack.socket("length-prefixed")
    rt.channel(b, shared)
    b.deliver(build_message(np.arange(3), pb))
    rt.run()
    wire = shared.tx_wire()
    assert shared.pending_send is None
    assert np.array_equal(wire[6:46], pa)
    assert np.array_equal(wire[-40:], pb)
    stack.drain()
    assert stack.alloc.free_pages == stack.alloc.total_pages


# ---------------------------------------------------------------------------
# backpressure (pool watermark)
# ---------------------------------------------------------------------------

def test_backpressure_pauses_ingress_instead_of_drain_overflow():
    """With backpressure on, channels pause while the pool sits above its
    watermark, so the same overflow workload completes with ZERO §A.1
    full-copy drain tokens; with backpressure off it must overflow."""
    def run(backpressure):
        stack = _stack(n_shards=1, pages_per_shard=10, page_size=16)
        stack.high_watermark = 0.5
        rt = ProxyRuntime(stack, tick_every=4, batched=True)
        dsts = _load(stack, rt, n_chans=4, n_msgs=2, payload=64,
                     protos=("length-prefixed",), backpressure=backpressure)
        rt.run()
        msgs = rt.messages_forwarded()
        pauses = sum(c.stats.bp_pauses for c in rt.channels)
        rt.shutdown()
        assert stack.alloc.free_pages == stack.alloc.total_pages
        return stack.counters, msgs, pauses

    c_on, msgs_on, pauses_on = run(True)
    c_off, msgs_off, _ = run(False)
    assert msgs_on == msgs_off == 8          # same work completes either way
    assert c_on.full_copied == 0             # paused, never overflowed
    assert pauses_on > 0
    assert c_off.full_copied > 0             # §A.1 drain engaged without bp
    assert c_on.anchored == c_on.zero_copied # every payload stayed zero-copy


def test_backpressure_liveness_when_only_paused_work_remains():
    """If backpressure is the ONLY thing holding work back (nothing in
    flight can free pages), the scheduler must admit the paused channels
    rather than deadlock — worst case they take the §A.1 drain path."""
    stack = _stack(n_shards=1, pages_per_shard=3, page_size=16)
    stack.high_watermark = 0.3
    rt = ProxyRuntime(stack, tick_every=4)
    src, dst = stack.socket_pair("length-prefixed")
    rt.channel(src, dst)                     # backpressure defaults on
    payload = RNG.integers(1000, 2000, 64)   # 4 pages > 3-page pool
    src.deliver(build_message(np.arange(3), payload))
    rt.run()
    assert rt.messages_forwarded() >= 1
    assert np.array_equal(dst.tx_wire()[-64:], payload)
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages


def test_stack_exposes_watermark():
    stack = _stack(n_shards=1, pages_per_shard=10, page_size=16)
    assert not stack.above_watermark()
    stack.high_watermark = 0.25
    pages = stack.alloc.alloc_sequence(3 * 16)
    assert stack.above_watermark()
    stack.alloc.free_pages_list(pages)
    assert not stack.above_watermark()


# ---------------------------------------------------------------------------
# latency telemetry
# ---------------------------------------------------------------------------

def test_latency_histogram_percentiles():
    h = LatencyHistogram(lo=1e-6)
    for _ in range(90):
        h.record(1e-5)
    for _ in range(10):
        h.record(1e-2)
    assert h.count == 100
    assert 2e-6 < h.percentile(0.5) < 5e-5       # near the bulk
    assert h.percentile(0.99) > 1e-3             # tail bucket
    s = h.summary()
    assert s["count"] == 100 and s["p99"] >= s["p50"] > 0


def test_runtime_reports_quantum_latency():
    stack = _stack()
    for batched in (False, True):
        rt = ProxyRuntime(stack, batched=batched)
        _load(stack, rt, n_chans=2, n_msgs=2, protos=("length-prefixed",),
              seed=batched)
        rt.run()
        summary = rt.latency_summary()
        assert len(summary) == 2
        for stats in summary.values():
            assert stats["count"] > 0
            assert stats["p99"] >= stats["p50"] > 0
    stack.close_all()
