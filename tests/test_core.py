"""Libra core: parser policies, state machines, VPI registry, anchor pool,
end-to-end ingress/egress — unit + hypothesis property tests."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    AnchorPool,
    ChunkedParser,
    Connection,
    CopyCounters,
    DelimiterParser,
    LengthPrefixedParser,
    PoolExhausted,
    St,
    TokenPool,
    VpiRegistry,
    build_chunked_message,
    build_delimited_message,
    build_message,
    expire_teardowns,
    kmp_find,
    libra_close,
    libra_recv,
    libra_send,
)

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# parsers
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=0, max_size=200),
       st.lists(st.integers(0, 50), min_size=1, max_size=5))
def test_kmp_matches_naive(hay, pat):
    hay = np.array(hay, np.int64)
    want = -1
    for i in range(len(hay) - len(pat) + 1):
        if list(hay[i : i + len(pat)]) == pat:
            want = i
            break
    assert kmp_find(hay, pat) == want


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 40), st.integers(0, 300))
def test_length_prefixed_roundtrip(meta_n, payload_n):
    meta = RNG.integers(100, 200, meta_n)
    payload = RNG.integers(1000, 2000, payload_n)
    msg = build_message(meta, payload)
    res = LengthPrefixedParser().parse(msg)
    assert res.ok
    assert res.meta_len == 3 + meta_n
    assert res.payload_len == payload_n


def test_delimiter_parser():
    meta = RNG.integers(100, 200, 7)
    payload = RNG.integers(1000, 2000, 40)
    msg = build_delimited_message(meta, payload)
    res = DelimiterParser().parse(msg)
    assert res.ok and res.payload_len == 40
    assert res.meta_len == 7 + 4 + 1  # meta + delim + length slot


def test_chunked_parser():
    chunks = [RNG.integers(0, 9, n) for n in (10, 3, 25)]
    msg = build_chunked_message(chunks)
    p = ChunkedParser()
    off = 0
    seen = []
    while True:
        res = p.parse(msg[off:])
        assert res.ok
        if res.payload_len == 0:
            break
        seen.append(res.payload_len)
        off += res.consumed + res.payload_len
    assert seen == [10, 3, 25]


def test_parser_incomplete_window():
    assert LengthPrefixedParser().parse(np.array([17], np.int64)).need_more
    assert not LengthPrefixedParser().parse(np.array([99, 1, 2], np.int64)).ok


# ---------------------------------------------------------------------------
# VPI registry
# ---------------------------------------------------------------------------

def test_vpi_opacity_and_roundtrip():
    reg = VpiRegistry(secret=b"k")
    v = reg.register("p", [(0, 1, 0)], 100)
    assert v != 0
    tok = VpiRegistry.to_token(v)
    assert VpiRegistry.from_token(tok) == v
    # secure mapping: handles from different registries/secrets differ
    reg2 = VpiRegistry(secret=b"other")
    assert reg2.register("p", [(0, 1, 0)], 100) != v


def test_vpi_refcount_and_teardown():
    reg = VpiRegistry(secret=b"k", grace_ticks=3)
    v = reg.register("p", [(0, 0, 0)], 50)
    reg.retain(v)
    assert not reg.release(v)
    assert v in reg
    reg.begin_teardown(v, now_tick=0)
    assert reg.resolve(v) is None           # teardown entries don't resolve
    assert reg.expire_teardowns(2) == []    # grace not elapsed
    assert len(reg.expire_teardowns(3)) == 1
    assert v not in reg


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 200))
def test_vpi_unique(n):
    reg = VpiRegistry(secret=b"k")
    vs = [reg.register("p", [], 10) for _ in range(n)]
    assert len(set(vs)) == n


# ---------------------------------------------------------------------------
# anchor pool
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 300), min_size=1, max_size=20))
def test_pool_alloc_free_invariants(lengths):
    pool = AnchorPool(n_shards=4, pages_per_shard=32, page_size=16)
    seqs = []
    for ln in lengths:
        try:
            seqs.append(pool.alloc_sequence(ln))
        except PoolExhausted:
            break
    # no page is double-allocated
    all_pages = [(p.shard, p.local_pid) for s in seqs for p in s]
    assert len(all_pages) == len(set(all_pages))
    for s in seqs:
        pool.free_pages_list(s)
    assert pool.free_pages == pool.total_pages
    assert pool.accounted_pages == 0


def test_pool_admission_cap():
    pool = AnchorPool(n_shards=1, pages_per_shard=64, page_size=16,
                      max_pages_per_seq=4)
    with pytest.raises(PoolExhausted):
        pool.alloc_sequence(16 * 10)  # exceeds the §A.1 cap
    assert pool.stats["fallbacks"] == 1


def test_pool_two_phase_transfer_accounting():
    pool = AnchorPool(n_shards=2, pages_per_shard=8, page_size=16)
    pages = pool.alloc_sequence(100)
    staged = pool.stage_transfer(pages)
    assert pool._budget_raise == len(staged)  # §A.3 temporary raise
    owned = pool.commit_transfer(staged)
    assert pool._budget_raise == 0
    pool.free_pages_list(owned)
    assert pool.free_pages == pool.total_pages


def test_pool_refcount_prefix_sharing():
    pool = AnchorPool(n_shards=1, pages_per_shard=8, page_size=16)
    pages = pool.alloc_sequence(60)
    pool.retain(pages)
    pool.free_pages_list(pages)
    assert pool.free_pages < pool.total_pages  # still held
    pool.free_pages_list(pages)
    assert pool.free_pages == pool.total_pages


# ---------------------------------------------------------------------------
# end-to-end ingress/egress (paper Fig. 3b flow)
# ---------------------------------------------------------------------------

def _setup(min_payload=8):
    alloc = AnchorPool(n_shards=4, pages_per_shard=64, page_size=16)
    pool = TokenPool(alloc)
    reg = VpiRegistry(secret=b"t")
    parser = LengthPrefixedParser()
    mk = lambda: Connection(parser, reg, min_payload=min_payload)
    return pool, reg, mk, CopyCounters()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 20), st.integers(8, 400), st.integers(1, 4))
def test_proxy_flow_payload_intact(meta_n, payload_n, n_msgs):
    """Any message stream: payloads arrive intact with zero payload copies
    across the user boundary; VPIs and pages are fully reclaimed."""
    pool, reg, mk, counters = _setup()
    cin, cout = mk(), mk()
    payloads = []
    for _ in range(n_msgs):
        meta = RNG.integers(100, 200, meta_n)
        payload = RNG.integers(1000, 2000, payload_n)
        payloads.append(payload)
        cin.deliver(build_message(meta, payload))
    for payload in payloads:
        buf, logical = libra_recv(cin, 1 << 20, pool, reg, counters)
        new_meta = np.array([17, 0, payload_n], np.int64)
        out = np.concatenate([new_meta, buf[-1:]])
        sent = libra_send(cin, cout, out, pool, reg, counters)
        assert sent == 3 + payload_n
        wire = cout.tx_stream[-1]
        assert np.array_equal(wire[3:], payload)
    assert len(reg) == 0
    assert pool.alloc.free_pages == pool.alloc.total_pages
    # selective copy: user-boundary copies are metadata-sized only
    assert counters.meta_copied <= n_msgs * (meta_n + 3 + 3)
    assert counters.zero_copied == n_msgs * payload_n


def test_fallback_on_vpi_miss():
    """Garbage VPI slot -> FALLBACK_BYPASS full-copy path (Fig. 5)."""
    pool, reg, mk, counters = _setup()
    cin, cout = mk(), mk()
    meta = RNG.integers(100, 200, 4)
    fake = np.concatenate([build_message(meta, np.array([], np.int64))[:3],
                           meta, np.array([123456789], np.int64)])
    fake[2] = 50  # claims a 50-token payload; VPI slot is garbage
    sent = libra_send(cin, cout, fake, pool, reg, counters)
    assert cout.tx_machine.state == St.FALLBACK_BYPASS
    assert counters.full_copied > 0 and counters.zero_copied == 0


def test_small_buffer_metadata_parsed_then_vpi():
    """Tiny user buffer: METADATA_PARSED defers the VPI until space exists
    (Fig. 4 boxes 2-3)."""
    pool, reg, mk, counters = _setup()
    c = mk()
    meta = RNG.integers(100, 200, 6)
    payload = RNG.integers(1000, 2000, 64)
    c.deliver(build_message(meta, payload))
    buf1, n1 = libra_recv(c, 4, pool, reg, counters)     # too small for VPI
    assert c.rx_machine.state == St.METADATA_PARSED
    assert len(buf1) == 4
    buf2, n2 = libra_recv(c, 1 << 16, pool, reg, counters)
    assert c.rx_machine.state == St.FAST_PATH
    assert len(buf2) == (3 + 6 - 4) + 1  # remaining meta + VPI
    assert n2 >= 64


def test_pool_exhaustion_falls_back_to_copy():
    alloc = AnchorPool(n_shards=1, pages_per_shard=2, page_size=16)
    pool = TokenPool(alloc)
    reg = VpiRegistry(secret=b"t")
    c = Connection(LengthPrefixedParser(), reg, min_payload=8)
    counters = CopyCounters()
    payload = RNG.integers(1000, 2000, 200)  # needs 13 pages > 2
    c.deliver(build_message(RNG.integers(0, 9, 2), payload))
    out_parts = []
    total = 0
    for _ in range(50):
        buf, n = libra_recv(c, 64, pool, reg, counters)
        out_parts.append(buf)
        total += n
        if c.rx_available() == 0:
            break
    got = np.concatenate(out_parts)
    assert counters.full_copied > 0 and len(reg) == 0
    assert np.array_equal(got[-200:], payload)  # data still correct
