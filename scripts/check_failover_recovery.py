"""CI gate: failover-recovery invariants of the fault-tolerance layer.

Runs the standard chaos scenario (backend reset at t=25%, worker kill at
t=50%, policy table hot-swap at t=75% — times pinned to the fault-free
round count) from :mod:`benchmarks.bench_chaos_proxy` at a small fixed
size and asserts, deterministically (seeded FaultPlan, no wall-clock
thresholds):

1. **Identity** — every message delivered under chaos is byte-identical
   to one the fault-free run delivered, exactly once; every missing
   message is a counted drop (no silent loss).
2. **Recovery machinery engaged** — the breaker/failover path or the
   retry loop actually fired, one worker was killed and its live flows
   migrated, and the surviving tables run at the swapped epoch.
3. **Zero leaks** — every pool drains to fully-free with no grant pins
   outstanding (asserted inside ``ClusterRuntime.shutdown``).

Run: ``PYTHONPATH=src python scripts/check_failover_recovery.py``
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.bench_chaos_proxy import check_identity, run_scenario  # noqa: E402


def main() -> int:
    n_chans, n_msgs, payload = 9, 12, 32
    steady = run_scenario(chaos=False, n_chans=n_chans, n_msgs=n_msgs,
                          payload=payload)
    assert steady["drops"] == 0 and steady["msgs"] == n_chans * n_msgs
    print(f"steady:  msgs={steady['msgs']} rounds={steady['rounds']} "
          f"drops=0")

    chaos = run_scenario(chaos=True, n_chans=n_chans, n_msgs=n_msgs,
                         payload=payload, steady_rounds=steady["rounds"])
    check_identity(chaos, steady)
    cs = chaos["cluster_stats"]
    assert cs["worker_kills"] == 1, "the worker kill never fired"
    assert cs["migrated_flows"] >= 1, "no live flow migrated off the worker"
    assert chaos["failovers"] + chaos["retries"] > 0, \
        "neither the retry loop nor the failover path engaged"
    assert chaos["msgs"] + chaos["drops"] == n_chans * n_msgs
    print(f"chaos:   msgs={chaos['msgs']} drops={chaos['drops']} "
          f"retries={chaos['retries']} failovers={chaos['failovers']} "
          f"migrated={cs['migrated_flows']} "
          f"fault_hits={chaos['fault_summary']['hits_by_kind']}")
    print("failover recovery: OK (identity + conservation + zero leaks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
