#!/usr/bin/env python
"""Benchmark trend gate: diff fresh ``results/bench/BENCH_*.json`` against
the committed (git HEAD) baselines and FAIL on a throughput regression.

For every artifact present in the working tree, the committed version is
read via ``git show HEAD:<path>``. Rows are matched by ``name``; every
shared throughput metric (``msgs_per_s``, ``rounds_per_s``, and the kTLS
``hw_over_sw``/``hw_fused_over_sw`` ratios) must not drop
below ``(1 - tolerance)`` of its baseline (default tolerance 30%, i.e. a
>30% regression fails — override with ``LIBRA_TREND_TOLERANCE``).

Throughput samples on a shared box are noisy; a one-off slow sample must
not fail the build. When a metric trips, the gate **re-runs that one
benchmark module** (``python -m benchmarks.run --smoke --only <bench>``,
refreshing its artifact) and re-compares: a metric only FAILS if the
regression persists on the confirmation run; a recovered metric is
reported as noise.

Warn-only cases (never fail):
  * no committed baseline for an artifact (a brand-new benchmark),
  * baseline and fresh run disagree on smoke mode (different regimes),
  * a row/metric present on only one side.

Exit status: 0 = ok (possibly with warnings), 1 = at least one confirmed
regression.

  PYTHONPATH=src python scripts/check_bench_trend.py [--dir results/bench]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

METRICS = ("msgs_per_s", "rounds_per_s", "hw_over_sw", "hw_fused_over_sw")


def _baseline(repo: str, relpath: str):
    """The committed (HEAD) version of ``relpath``, or None."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{relpath}"], cwd=repo,
            capture_output=True, check=True)
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, json.JSONDecodeError,
            FileNotFoundError):
        return None


def _rows_by_name(doc) -> dict:
    return {r["name"]: r for r in doc.get("rows", []) if "name" in r}


def _rerun_bench(repo: str, bench: str, smoke: bool) -> bool:
    """Confirmation run for one benchmark module (refreshes its artifact).
    Returns False when the re-run itself failed."""
    cmd = [sys.executable, "-m", "benchmarks.run", "--only", bench]
    if smoke:
        cmd.append("--smoke")
    env = dict(os.environ)
    src = os.path.join(repo, "src")
    prev = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + prev if prev else "")
    try:
        subprocess.run(cmd, cwd=repo, env=env, check=True,
                       capture_output=True, timeout=600)
        return True
    except (subprocess.CalledProcessError, subprocess.TimeoutExpired):
        return False


def _compare(rel: str, base: dict, fresh: dict, tolerance: float):
    """(failing, warnings, checked) for one artifact pair."""
    failing, warnings, checked = [], [], 0
    brows, frows = _rows_by_name(base), _rows_by_name(fresh)
    for name, brow in brows.items():
        frow = frows.get(name)
        if frow is None:
            warnings.append(f"{rel}: row '{name}' vanished")
            continue
        for m in METRICS:
            if m not in brow:
                continue
            if m not in frow:
                warnings.append(f"{rel}: {name}.{m} vanished")
                continue
            b, f = float(brow[m]), float(frow[m])
            if b <= 0:
                continue
            checked += 1
            ratio = f / b
            if ratio < 1.0 - tolerance:
                failing.append(
                    f"{name}.{m} {b:.0f} -> {f:.0f} "
                    f"({(1 - ratio) * 100:.0f}% regression)")
    return failing, warnings, checked


def check(repo: str, bench_dir: str, tolerance: float):
    regressions, warnings, checked = [], [], 0
    for path in sorted(glob.glob(os.path.join(repo, bench_dir,
                                              "BENCH_*.json"))):
        rel = os.path.relpath(path, repo)
        fresh = json.load(open(path))
        base = _baseline(repo, rel)
        if base is None:
            warnings.append(f"{rel}: no committed baseline (new benchmark)")
            continue
        if bool(fresh.get("smoke")) != bool(base.get("smoke")):
            warnings.append(f"{rel}: smoke-mode mismatch vs baseline "
                            f"(fresh={fresh.get('smoke')}, "
                            f"base={base.get('smoke')}) — skipped")
            continue
        failing, warns, n = _compare(rel, base, fresh, tolerance)
        warnings += warns
        checked += n
        if failing:
            # noisy-sample guard: confirm on a fresh run of just this
            # module before failing the build
            bench = fresh.get("bench", "")
            print(f"RETRY {rel}: {len(failing)} metric(s) tripped — "
                  f"re-running '{bench}' to confirm", flush=True)
            if bench and _rerun_bench(repo, bench, bool(fresh.get("smoke"))):
                fresh2 = json.load(open(path))
                confirmed, _, _ = _compare(rel, base, fresh2, tolerance)
                for msg in failing:
                    if not any(c.split(" ")[0] == msg.split(" ")[0]
                               for c in confirmed):
                        warnings.append(
                            f"{rel}: {msg} — recovered on re-run (noise)")
                failing = confirmed
            regressions += [f"{rel}: {msg}" for msg in failing]
    return regressions, warnings, checked


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join("results", "bench"))
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("LIBRA_TREND_TOLERANCE",
                                                 "0.30")))
    args = ap.parse_args()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    regressions, warnings, checked = check(repo, args.dir, args.tolerance)
    for w in warnings:
        print(f"WARN  {w}")
    for r in regressions:
        print(f"FAIL  {r}")
    print(f"bench-trend: {checked} metric(s) checked, "
          f"{len(regressions)} regression(s), {len(warnings)} warning(s) "
          f"(tolerance {args.tolerance:.0%})")
    return 1 if regressions else 0


if __name__ == "__main__":
    raise SystemExit(main())
