#!/usr/bin/env python
"""CI gate for the datapath verifier (``repro.analysis``).

Runs the static-analysis passes — page/grant ownership lint, jaxpr
zero-copy audit, cluster-plane lockset check, the concurrency verifier
(lock order, atomicity, steal path), and the import-graph hygiene check —
and fails on any unwaived finding. A wall-clock budget keeps the gate
honest: static analysis that takes minutes stops being run, so the whole
suite must finish in under 30 s on CPU.

Usage: python scripts/check_static_analysis.py
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

WALL_BUDGET_S = 30.0


def main() -> int:
    t0 = time.monotonic()
    failed = False

    from repro.analysis import ownership
    rep = ownership.run()
    print("\n".join(rep.lines()))
    failed |= not rep.ok

    from repro.analysis import jaxpr_audit
    rep = jaxpr_audit.run()
    print("\n".join(rep.lines()))
    failed |= not rep.ok

    from repro.analysis import lockset
    rep = lockset.run()
    print("\n".join(rep.lines()))
    failed |= not rep.ok

    from repro.analysis import concurrency
    rep = concurrency.run()
    print("\n".join(rep.lines()))
    failed |= not rep.ok

    from repro.analysis import importgraph
    rep = importgraph.run()
    print(rep.summary())
    for f in rep.active:
        print("  " + f.format())
    failed |= not rep.ok

    wall = time.monotonic() - t0
    print(f"static analysis wall clock: {wall:.1f}s (budget {WALL_BUDGET_S:.0f}s)")
    if wall > WALL_BUDGET_S:
        print("FAIL: static analysis exceeded its wall-clock budget — "
              "a slow gate is a skipped gate; profile the offending pass")
        failed = True

    if failed:
        print("check_static_analysis: FAIL")
        return 1
    print("check_static_analysis: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
