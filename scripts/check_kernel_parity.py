"""CI gate: the fused selective-copy/gather kernels vs the pure-jnp
oracles.

Checks (seconds-fast, CPU-only), sharing case/walk machinery with
tests/test_kernels.py via :mod:`repro.kernels.testing`:

1. **Interpret-mode parity** — the fused Pallas kernel bodies (executed on
   CPU via ``interpret=True``) must match their ``kernels.ref`` oracles
   bit-exactly across a shape/boundary sweep: ingress ``selective_copy``
   (legacy + reserved-scratch modes, plus the hw-kTLS ``keystream``
   operand) and the egress ``selective_gather`` (± keystream).
2. **Zero-realloc / in-place hot paths** — neither kernel's jaxpr may
   contain ``concatenate``/``pad`` (a pool-sized copy): the ingress kernel
   runs over the reserved scratch row, the gather reads the resident pool
   where it lives.

Run: ``PYTHONPATH=src python scripts/check_kernel_parity.py``
"""
from __future__ import annotations

import functools
import sys

import numpy as np

from repro.analysis.jaxpr_audit import assert_fused
from repro.kernels import ref as R
from repro.kernels.selective_copy import (
    fused_round,
    policy_match,
    selective_copy,
    selective_gather,
)
from repro.kernels.testing import (
    fused_round_case,
    policy_case,
    policy_live_column,
    policy_payload_case,
    selcopy_case,
    selcopy_crypto_case,
    selgather_case,
)


def check_parity() -> None:
    rng = np.random.default_rng(42)
    for b, page, pps, meta_max in [(1, 8, 2, 8), (2, 8, 4, 16),
                                   (3, 16, 4, 16), (2, 16, 3, 32)]:
        stream, ml, tl, pool, tables = selcopy_case(
            rng, b=b, page=page, pps=pps, meta_max=meta_max)
        for reserved in (True, False):
            pl_pool = pool if reserved else pool[:-1]
            got_m, got_p = selective_copy(stream, ml, tl, pl_pool, tables,
                                          meta_max=meta_max, interpret=True,
                                          reserved_scratch=reserved)
            want_m, want_p = R.selective_copy_ref(stream, ml, tl, pl_pool,
                                                  tables, meta_max=meta_max)
            assert np.array_equal(np.array(got_m), np.array(want_m)), \
                (b, page, pps, meta_max, reserved, "meta")
            assert np.array_equal(np.array(got_p), np.array(want_p)), \
                (b, page, pps, meta_max, reserved, "pool")
    print("parity: fused kernel == oracle (bit-exact, interpret mode)")


def check_crypto_parity() -> None:
    """The keystream operand (kTLS-analogue hw mode): fused kernel with
    inline XOR decrypt vs ``selective_copy_crypto_ref``, bit-exact."""
    rng = np.random.default_rng(43)
    for b, page, pps, meta_max in [(1, 8, 2, 8), (2, 8, 4, 16),
                                   (3, 16, 4, 16), (2, 16, 3, 32)]:
        stream, ml, tl, pool, tables, ks = selcopy_crypto_case(
            rng, b=b, page=page, pps=pps, meta_max=meta_max)
        got_m, got_p = selective_copy(stream, ml, tl, pool, tables,
                                      meta_max=meta_max, interpret=True,
                                      reserved_scratch=True, keystream=ks)
        want_m, want_p = R.selective_copy_crypto_ref(
            stream, ml, tl, pool, tables, ks, meta_max=meta_max)
        assert np.array_equal(np.array(got_m), np.array(want_m)), \
            (b, page, pps, meta_max, "crypto-meta")
        assert np.array_equal(np.array(got_p), np.array(want_p)), \
            (b, page, pps, meta_max, "crypto-pool")
    print("parity: keystream operand == crypto oracle (bit-exact)")


def check_gather_parity() -> None:
    """The egress gather kernel (resident-pool readback, with and without
    the TX keystream operand) vs ``selective_gather_ref``, bit-exact."""
    rng = np.random.default_rng(44)
    for b, page, pps in [(1, 8, 2), (2, 8, 4), (3, 16, 4), (2, 16, 3)]:
        pool, tables, lengths, ks = selgather_case(rng, b=b, page=page,
                                                   pps=pps)
        for k in (None, ks):
            got = selective_gather(pool, tables, lengths, interpret=True,
                                   keystream=k)
            want = R.selective_gather_ref(pool, tables, lengths, k)
            assert np.array_equal(np.array(got), np.array(want)), \
                (b, page, pps, k is not None, "gather")
    print("parity: egress gather == oracle (bit-exact, +keystream)")


def check_gather_no_pool_copy() -> None:
    pool, tables, lengths, ks = selgather_case(np.random.default_rng(8))
    for k in (None, ks):
        fn = functools.partial(selective_gather, interpret=True, keystream=k)
        assert_fused(fn, (pool, tables, lengths),
                     name=f"gather[ks={k is not None}]")
    print("zero-copy: gather jaxpr reads the resident pool in place")


def check_no_pool_copy() -> None:
    stream, ml, tl, pool, tables = selcopy_case(np.random.default_rng(7))
    fn = functools.partial(selective_copy, meta_max=16, interpret=True,
                           reserved_scratch=True)
    assert_fused(fn, (stream, ml, tl, pool, tables), name="selcopy")
    legacy = functools.partial(selective_copy, meta_max=16, interpret=True,
                               reserved_scratch=False)
    # negative control: the legacy (non-fused) path must still show its
    # grown-pool concatenate, or the gate itself has gone blind
    assert_fused(legacy, (stream, ml, tl, pool[:-1], tables),
                 name="selcopy[legacy]", forbid=(), expect=("concatenate",))
    print("zero-realloc: reserved-scratch jaxpr has no concatenate/pad")


def check_policy_parity() -> None:
    """The L7 policy first-match kernel vs ``policy_match_ref``, bit-exact
    across shapes, with and without the hw-kTLS keystream operand (the
    kernel matches ciphertext XOR keystream) and the backend-health
    ``live`` rule mask (dead rules must lose the first-match scan)."""
    rng = np.random.default_rng(45)
    for b, meta_max, r, k in [(1, 8, 2, 1), (4, 16, 6, 3), (3, 32, 8, 2),
                              (8, 16, 4, 4)]:
        meta, ml, off, lo, hi, ks = policy_case(rng, b=b, meta_max=meta_max,
                                                r=r, k=k)
        live = policy_live_column(rng, r)
        for kk in (None, ks):
            for lv in (None, live):
                m = meta if kk is None else np.bitwise_xor(np.array(meta),
                                                           np.array(kk))
                got = policy_match(m, ml, off, lo, hi, interpret=True,
                                   keystream=kk, live=lv)
                want = R.policy_match_ref(m, ml, off, lo, hi, kk, lv)
                assert np.array_equal(np.array(got), np.array(want)), \
                    (b, meta_max, r, k, kk is not None, lv is not None,
                     "policy")
    print("parity: policy-match kernel == oracle (bit-exact, "
          "+keystream, +live)")


def check_payload_policy_parity() -> None:
    """Payload-prefix conditions (``cond_off <= -2`` peeking the first
    anchored page window) vs the oracle, bit-exact, ± keystream ± live."""
    rng = np.random.default_rng(46)
    for b, meta_max, r, k, w in [(1, 8, 2, 1, 8), (4, 16, 6, 3, 8),
                                 (3, 16, 8, 2, 16)]:
        meta, ml, off, lo, hi, ks, pay, plen = policy_payload_case(
            rng, b=b, meta_max=meta_max, r=r, k=k, w=w)
        live = policy_live_column(rng, r)
        for kk in (None, ks):
            for lv in (None, live):
                m = meta if kk is None else np.bitwise_xor(np.array(meta),
                                                           np.array(kk))
                got = policy_match(m, ml, off, lo, hi, interpret=True,
                                   keystream=kk, live=lv, payload=pay,
                                   payload_len=plen)
                want = R.policy_match_ref(m, ml, off, lo, hi, kk, lv,
                                          payload=pay, payload_len=plen)
                assert np.array_equal(np.array(got), np.array(want)), \
                    (b, meta_max, r, k, w, kk is not None, lv is not None,
                     "payload-policy")
    print("parity: payload-prefix conditions == oracle (bit-exact)")


def check_fused_round_parity() -> None:
    """The one-kernel scheduling round vs ``fused_round_ref`` across the
    optional-operand matrix (crypto keystreams, policy table, live column,
    metadata keystream) and the DMA-staged buffer depths — meta, pool,
    verdict, and gathered payload all bit-exact."""
    rng = np.random.default_rng(47)
    for b, page, pps, meta_max in [(1, 8, 2, 8), (2, 8, 4, 16)]:
        case = fused_round_case(rng, b=b, page=page, pps=pps,
                                meta_max=meta_max)
        base = (case["stream"], case["meta_len"], case["total_len"],
                case["pool"], case["tables"])
        for crypto in (False, True):
            for policy in (False, True):
                kw = dict(meta_max=meta_max)
                if crypto:
                    kw.update(keystream=case["keystream"],
                              tx_keystream=case["tx_keystream"])
                if policy:
                    kw.update(cond_off=case["cond_off"],
                              cond_lo=case["cond_lo"],
                              cond_hi=case["cond_hi"], live=case["live"])
                    if crypto:
                        kw.update(meta_ks=case["meta_ks"])
                want = R.fused_round_ref(*base, **kw)
                # quad buffering only for the full-operand combo (each
                # extra depth is a fresh interpret compile; 2 covers the
                # staged control flow, 4 only the ring-index arithmetic)
                depths = (0, 2, 4) if (crypto and policy) else (0, 2)
                for n_buffers in depths:
                    got = fused_round(*base, interpret=True,
                                      n_buffers=n_buffers, **kw)
                    for gi, wi, tag in zip(got, want,
                                           ("meta", "pool", "verdict",
                                            "gathered")):
                        if wi is None:
                            assert gi is None, (tag, "verdict expected None")
                            continue
                        assert np.array_equal(np.array(gi), np.array(wi)), \
                            (b, page, pps, meta_max, crypto, policy,
                             n_buffers, tag)
    print("parity: one-kernel fused round == oracle (bit-exact, "
          "crypto/policy matrix, DMA-staged depths)")


def check_fused_round_single_launch() -> None:
    """The fusion claim itself: the full-operand round traces to exactly
    ONE pallas_call with no pool-sized copy (3-to-1 launch collapse), in
    both the blocked and the DMA-staged layouts."""
    case = fused_round_case(np.random.default_rng(12))
    args = (case["stream"], case["meta_len"], case["total_len"],
            case["pool"], case["tables"])
    for n_buffers in (0, 2):
        fn = functools.partial(
            fused_round, meta_max=16, interpret=True, n_buffers=n_buffers,
            keystream=case["keystream"], tx_keystream=case["tx_keystream"],
            cond_off=case["cond_off"], cond_lo=case["cond_lo"],
            cond_hi=case["cond_hi"], live=case["live"],
            meta_ks=case["meta_ks"])
        assert_fused(fn, args, name=f"fused_round[nb={n_buffers}]")
    print("one-kernel: fused round jaxpr is a single pallas_call "
          "(blocked + DMA-staged)")


def check_policy_no_pool_copy() -> None:
    """The match pass touches only the round's [B, M] metadata block — its
    jaxpr must contain no pool-sized copy primitive and exactly one fused
    kernel call (the health column rides along without adding a pass)."""
    rng = np.random.default_rng(9)
    meta, ml, off, lo, hi, ks = policy_case(rng)
    live = policy_live_column(rng, off.shape[0])
    for kk in (None, ks):
        for lv in (None, live):
            fn = functools.partial(policy_match, interpret=True,
                                   keystream=kk, live=lv)
            assert_fused(fn, (meta, ml, off, lo, hi),
                         name=f"policy[ks={kk is not None},"
                              f"live={lv is not None}]")
    print("zero-copy: policy match jaxpr is one fused kernel call")


if __name__ == "__main__":
    check_parity()
    check_crypto_parity()
    check_gather_parity()
    check_policy_parity()
    check_payload_policy_parity()
    check_fused_round_parity()
    check_no_pool_copy()
    check_gather_no_pool_copy()
    check_policy_no_pool_copy()
    check_fused_round_single_launch()
    print("check_kernel_parity: OK")
    sys.exit(0)
