#!/usr/bin/env bash
# One-command builder gate: tier-1 tests + example/benchmark smoke.
#   bash scripts/verify.sh [--fast]   (--fast skips the jit-heavy quickstart)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== kernel parity: fused selective-copy + gather + policy-match vs oracles (interpret mode) =="
python scripts/check_kernel_parity.py

echo "== static analysis: ownership + jaxpr + lockset + concurrency + imports =="
python scripts/check_static_analysis.py

if command -v ruff >/dev/null 2>&1; then
  echo "== lint: ruff (hard gate: analysis/; advisory: rest) =="
  ruff check src/repro/analysis
  ruff check . || echo "ruff (advisory, outside analysis/): issues above are non-blocking"
else
  echo "== lint: ruff not installed — skipping (pip install -r requirements-dev.txt) =="
fi

if command -v mypy >/dev/null 2>&1; then
  echo "== types: mypy (hard gate: analysis/; advisory: rest) =="
  mypy src/repro/analysis
  mypy src/repro || echo "mypy (advisory, outside analysis/): issues above are non-blocking"
else
  echo "== types: mypy not installed — skipping (pip install -r requirements-dev.txt) =="
fi

echo "== failover recovery: standard chaos scenario (identity + conservation + zero leaks) =="
python scripts/check_failover_recovery.py

echo "== smoke: benchmarks/run.py --smoke =="
python -m benchmarks.run --smoke

echo "== bench trend gate: fresh artifacts vs committed baselines =="
python scripts/check_bench_trend.py

if [[ "${1:-}" != "--fast" ]]; then
  echo "== smoke: examples/quickstart.py =="
  python examples/quickstart.py
fi

echo "verify: OK"
